//! Deterministic observability for the serving engine.
//!
//! When `ServeOptions::trace` is armed, both engines (streaming
//! `run_events` and the frozen eager reference) drive a `Tracer`
//! through a small set of hooks at the exact logical points where the
//! simulation already makes its decisions: QoS admission, dispatch,
//! service start, completion, drop, priority eviction, and the
//! re-placement tick. The tracer turns those hooks into per-request
//! **spans** in virtual time —
//!
//! ```text
//! upload → queue → cold → gen → return
//! ```
//!
//! — plus discrete **events** (drop / evict / degrade / replace /
//! deadline-miss), all serialized as order-preserving JSON records.
//! Because every timestamp comes from the virtual clock and every
//! record is emitted at a point whose order is already pinned by the
//! determinism ladder, a trace is a pure function of the seed: double
//! runs are byte-identical and the streaming and eager engines emit
//! the same bytes (`rust/tests/serve_trace.rs`).
//!
//! The finished `TraceLog` renders in two formats — JSONL (one record
//! per line, the canonical bytes the FNV-1a trace hash covers) and
//! Chrome trace-event JSON (loadable in Perfetto: pid 1 carries one
//! track per worker, pid 2 one track per network link) — and folds
//! into windowed time-series (`TraceLog::windows`) for the `--window`
//! table and CSV emitter. See `docs/observability.md`.
//!
//! Span telescoping invariant: for every completed request the five
//! span durations sum to its recorded time-in-system *exactly* (the
//! interval endpoints telescope), which `serve_trace.rs` checks
//! against `ServeMetrics::decomposition_error()` tolerance.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::message::{Request, Response};
use super::network::Network;
use super::qos;
use super::router::EdfJob;
use crate::util::json::Json;

/// Trace schema identifier stamped into the leading meta record.
pub const TRACE_SCHEMA: &str = "dedgeai-trace-v1";

/// On-disk trace format selected by `--trace-format`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TraceFormat {
    /// One JSON record per line — the canonical hashed byte stream.
    #[default]
    Jsonl,
    /// Chrome trace-event JSON (`{"traceEvents": [...]}`), loadable
    /// in Perfetto / `chrome://tracing`.
    Chrome,
}

impl TraceFormat {
    pub fn parse(spec: &str) -> Result<TraceFormat> {
        match spec {
            "jsonl" => Ok(TraceFormat::Jsonl),
            "chrome" => Ok(TraceFormat::Chrome),
            other => {
                bail!("unknown trace format '{other}' (expected jsonl|chrome)")
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TraceFormat::Jsonl => "jsonl",
            TraceFormat::Chrome => "chrome",
        }
    }
}

/// Per-request state held between the admission hook and completion.
struct Pending {
    origin: usize,
    qos: usize,
    deadline: f64,
    submitted_at: f64,
    demanded_z: usize,
    demanded_model: usize,
    worker: usize,
    up: f64,
    gen: f64,
    down: f64,
    load_delay: f64,
    up_bits: f64,
    down_bits: f64,
    /// Virtual service-start time (generation begin, cold load already
    /// absorbed). NaN until the start hook fires.
    start: f64,
}

/// The live recorder the engines drive. Built once per run by
/// `DEdgeAi::make_tracer` when tracing is armed; consumed into a
/// `TraceLog` at drain time. All state is ordered (`BTreeMap`) and all
/// timestamps are virtual — the tracer draws zero RNG and never reads
/// the wall clock.
pub struct Tracer {
    workers: usize,
    nsites: usize,
    site_of: Vec<usize>,
    has_network: bool,
    pending: BTreeMap<u64, Pending>,
    records: Vec<Json>,
}

impl Tracer {
    pub fn new(workers: usize, network: Option<&Network>) -> Tracer {
        let nsites = network.map_or(1, |n| n.sites());
        let site_of: Vec<usize> =
            (0..workers).map(|w| network.map_or(0, |n| n.site(w))).collect();
        let site_json: Vec<f64> = site_of.iter().map(|&s| s as f64).collect();
        let meta = Json::from_pairs(vec![
            ("type", Json::str("meta")),
            ("schema", Json::str(TRACE_SCHEMA)),
            ("workers", Json::num(workers as f64)),
            ("sites", Json::num(nsites as f64)),
            ("site_of", Json::arr_f64(&site_json)),
        ]);
        Tracer {
            workers,
            nsites,
            site_of,
            has_network: network.is_some(),
            pending: BTreeMap::new(),
            records: vec![meta],
        }
    }

    /// QoS admission passed at `now`. `demanded_z` / `demanded_model`
    /// are the pre-degradation demand; if the admitted request was
    /// mutated (step reduction / model reroute) a `degrade` event is
    /// emitted here.
    pub fn admit(
        &mut self,
        req: &Request,
        demanded_z: usize,
        demanded_model: usize,
        now: f64,
    ) {
        self.pending.insert(
            req.id,
            Pending {
                origin: req.origin,
                qos: req.qos,
                deadline: req.deadline,
                submitted_at: req.submitted_at,
                demanded_z,
                demanded_model,
                worker: 0,
                up: 0.0,
                gen: 0.0,
                down: 0.0,
                load_delay: 0.0,
                up_bits: 0.0,
                down_bits: 0.0,
                start: f64::NAN,
            },
        );
        if req.z != demanded_z || req.model != demanded_model {
            self.records.push(Json::from_pairs(vec![
                ("type", Json::str("event")),
                ("kind", Json::str("degrade")),
                ("t", Json::num(now)),
                ("id", Json::num(req.id as f64)),
                ("qos", Json::num(req.qos as f64)),
                ("z", Json::num(req.z as f64)),
                ("demanded_z", Json::num(demanded_z as f64)),
                ("model", Json::num(req.model as f64)),
                ("demanded_model", Json::num(demanded_model as f64)),
            ]));
        }
    }

    /// The router chose `worker`; the charged leg durations are known.
    pub fn dispatch(
        &mut self,
        req: &Request,
        worker: usize,
        up: f64,
        gen: f64,
        down: f64,
        load_delay: f64,
    ) {
        if let Some(p) = self.pending.get_mut(&req.id) {
            p.worker = worker;
            p.up = up;
            p.gen = gen;
            p.down = down;
            p.load_delay = load_delay;
            if self.has_network {
                p.up_bits = Network::up_bits(req);
                p.down_bits = Network::down_bits(req);
            }
        }
    }

    /// Generation begins at virtual time `start` (cold load, if any,
    /// occupies `[start - load_delay, start]`).
    pub fn start(&mut self, id: u64, start: f64) {
        if let Some(p) = self.pending.get_mut(&id) {
            p.start = start;
        }
    }

    /// The request completed at `now`: emit its spans, the summary
    /// `req` record, and a `deadline-miss` event when applicable.
    pub fn complete(&mut self, resp: &Response, now: f64) {
        let Some(p) = self.pending.remove(&resp.id) else {
            return;
        };
        let id = resp.id;
        let t0 = p.submitted_at;
        let site = self.site_of.get(p.worker).copied().unwrap_or(0);
        let start = if p.start.is_nan() {
            t0 + p.up + p.load_delay
        } else {
            p.start
        };
        if self.has_network {
            self.span_link("upload", id, (p.origin, site), p.up_bits, t0, t0 + p.up);
        }
        self.span_worker("queue", id, p.worker, t0 + p.up, start - p.load_delay);
        if p.load_delay > 0.0 {
            self.span_worker("cold", id, p.worker, start - p.load_delay, start);
        }
        self.span_worker("gen", id, p.worker, start, start + p.gen);
        if self.has_network {
            self.span_link("return", id, (site, p.origin), p.down_bits, start + p.gen, now);
        }
        let missed = p.deadline.is_finite() && now > p.deadline;
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("req")),
            ("id", Json::num(id as f64)),
            ("worker", Json::num(p.worker as f64)),
            ("origin", Json::num(p.origin as f64)),
            ("qos", Json::num(p.qos as f64)),
            ("class", Json::str(qos::class(p.qos).name)),
            ("z", Json::num(resp.z as f64)),
            ("model", Json::num(resp.model as f64)),
            ("demanded_z", Json::num(p.demanded_z as f64)),
            ("demanded_model", Json::num(p.demanded_model as f64)),
            ("t0", Json::num(t0)),
            ("t1", Json::num(now)),
            ("latency", Json::num(resp.latency)),
            ("deadline", Json::num(p.deadline)),
            ("missed", Json::num(if missed { 1.0 } else { 0.0 })),
        ]));
        if missed {
            self.records.push(Json::from_pairs(vec![
                ("type", Json::str("event")),
                ("kind", Json::str("deadline-miss")),
                ("t", Json::num(now)),
                ("id", Json::num(id as f64)),
                ("worker", Json::num(p.worker as f64)),
                ("qos", Json::num(p.qos as f64)),
                ("over_s", Json::num(now - p.deadline)),
            ]));
        }
    }

    /// Admission drop (queue cap full, no eviction possible).
    pub fn drop_req(&mut self, now: f64, req: &Request) {
        self.pending.remove(&req.id);
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("drop")),
            ("t", Json::num(now)),
            ("id", Json::num(req.id as f64)),
            ("qos", Json::num(req.qos as f64)),
            ("origin", Json::num(req.origin as f64)),
        ]));
    }

    /// A parked EDF job was evicted from `worker` to admit `arrival`.
    pub fn evict(
        &mut self,
        now: f64,
        worker: usize,
        victim: &EdfJob,
        arrival: &Request,
    ) {
        self.pending.remove(&victim.req.id);
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("evict")),
            ("t", Json::num(now)),
            ("id", Json::num(victim.req.id as f64)),
            ("worker", Json::num(worker as f64)),
            ("qos", Json::num(victim.req.qos as f64)),
            ("z", Json::num(victim.req.z as f64)),
            ("demanded_z", Json::num(victim.demanded_z as f64)),
            ("model", Json::num(victim.req.model as f64)),
            ("demanded_model", Json::num(victim.demanded_model as f64)),
            ("by", Json::num(arrival.id as f64)),
            ("by_qos", Json::num(arrival.qos as f64)),
        ]));
    }

    /// Slow-timescale re-placement loaded `model` onto `worker`.
    pub fn replace(
        &mut self,
        now: f64,
        worker: usize,
        model: usize,
        delay_s: f64,
        evictions: usize,
    ) {
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("replace")),
            ("t", Json::num(now)),
            ("worker", Json::num(worker as f64)),
            ("model", Json::num(model as f64)),
            ("load_s", Json::num(delay_s)),
            ("cache_evictions", Json::num(evictions as f64)),
        ]));
    }

    /// Fault injection: every worker at `site` went down.
    pub fn site_down(&mut self, now: f64, site: usize) {
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("site-down")),
            ("t", Json::num(now)),
            ("site", Json::num(site as f64)),
        ]));
    }

    /// Fault injection: `site` recovered (cold).
    pub fn site_up(&mut self, now: f64, site: usize) {
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("site-up")),
            ("t", Json::num(now)),
            ("site", Json::num(site as f64)),
        ]));
    }

    /// Fault injection: link `from → to` degraded by `factor`, or
    /// restored (`factor == 1`).
    pub fn link_change(&mut self, now: f64, from: usize, to: usize, factor: f64) {
        let kind =
            if factor == 1.0 { "link-restore" } else { "link-degrade" };
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str(kind)),
            ("t", Json::num(now)),
            ("from", Json::num(from as f64)),
            ("to", Json::num(to as f64)),
            ("factor", Json::num(factor)),
        ]));
    }

    /// A running or parked job was killed by a site failure. The
    /// request stays pending — a retry may still serve it — but its
    /// dispatch-time fields are reset so the eventual completion's
    /// spans describe the *serving* dispatch, not the killed one.
    pub fn kill(&mut self, now: f64, id: u64, worker: usize) {
        if let Some(p) = self.pending.get_mut(&id) {
            p.worker = 0;
            p.up = 0.0;
            p.gen = 0.0;
            p.down = 0.0;
            p.load_delay = 0.0;
            p.up_bits = 0.0;
            p.down_bits = 0.0;
            p.start = f64::NAN;
        }
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("kill")),
            ("t", Json::num(now)),
            ("id", Json::num(id as f64)),
            ("worker", Json::num(worker as f64)),
        ]));
    }

    /// Re-dispatch attempt `attempt` for a killed request fired.
    pub fn retry(&mut self, now: f64, id: u64, attempt: u32) {
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("retry")),
            ("t", Json::num(now)),
            ("id", Json::num(id as f64)),
            ("attempt", Json::num(attempt as f64)),
        ]));
    }

    /// A killed request ran out of retry budget and was abandoned.
    pub fn exhaust(&mut self, now: f64, id: u64) {
        self.pending.remove(&id);
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("event")),
            ("kind", Json::str("retry-exhausted")),
            ("t", Json::num(now)),
            ("id", Json::num(id as f64)),
        ]));
    }

    /// Seal the recording.
    pub fn finish(self) -> TraceLog {
        TraceLog {
            workers: self.workers,
            nsites: self.nsites,
            site_of: self.site_of,
            records: self.records,
        }
    }

    fn span_worker(&mut self, phase: &str, id: u64, worker: usize, t0: f64, t1: f64) {
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("span")),
            ("phase", Json::str(phase)),
            ("id", Json::num(id as f64)),
            ("worker", Json::num(worker as f64)),
            ("t0", Json::num(t0)),
            ("t1", Json::num(t1)),
        ]));
    }

    fn span_link(
        &mut self,
        phase: &str,
        id: u64,
        link: (usize, usize),
        bits: f64,
        t0: f64,
        t1: f64,
    ) {
        self.records.push(Json::from_pairs(vec![
            ("type", Json::str("span")),
            ("phase", Json::str(phase)),
            ("id", Json::num(id as f64)),
            ("from", Json::num(link.0 as f64)),
            ("to", Json::num(link.1 as f64)),
            ("bits", Json::num(bits)),
            ("t0", Json::num(t0)),
            ("t1", Json::num(t1)),
        ]));
    }
}

/// A sealed trace: the ordered record list plus the worker/site map
/// needed to render tracks. Carried on `ServeMetrics` when tracing is
/// armed.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceLog {
    workers: usize,
    nsites: usize,
    site_of: Vec<usize>,
    records: Vec<Json>,
}

fn jf(r: &Json, k: &str) -> f64 {
    r.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
}

fn js<'a>(r: &'a Json, k: &str) -> &'a str {
    r.get(k).and_then(|v| v.as_str().ok()).unwrap_or("")
}

/// FNV-1a 64-bit over `bytes` — the trace-hash primitive. Stable,
/// dependency-free, and fast enough for multi-megabyte traces.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl TraceLog {
    pub fn records(&self) -> &[Json] {
        &self.records
    }

    /// Count records of a given `type` field value.
    pub fn count_type(&self, rtype: &str) -> usize {
        self.records.iter().filter(|r| js(r, "type") == rtype).count()
    }

    /// Count discrete events of a given kind (`drop`, `evict`, ...).
    pub fn count_events(&self, kind: &str) -> usize {
        self.records
            .iter()
            .filter(|r| js(r, "type") == "event" && js(r, "kind") == kind)
            .count()
    }

    /// Count spans of a given phase (`upload`, `queue`, `cold`, `gen`,
    /// `return`).
    pub fn count_spans(&self, phase: &str) -> usize {
        self.records
            .iter()
            .filter(|r| js(r, "type") == "span" && js(r, "phase") == phase)
            .count()
    }

    /// The canonical byte stream: one compact JSON record per line.
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.render());
            out.push('\n');
        }
        out
    }

    /// FNV-1a 64 over the JSONL bytes — the `verify-determinism`
    /// trace-hash column.
    pub fn hash(&self) -> u64 {
        fnv1a(self.render_jsonl().as_bytes())
    }

    /// Chrome trace-event JSON: pid 1 holds one thread per worker
    /// (queue/cold/gen spans), pid 2 one thread per observed network
    /// link (upload/return spans); discrete events become instants.
    /// Timestamps are virtual seconds scaled to microseconds.
    pub fn render_chrome(&self) -> String {
        let mut events: Vec<Json> = Vec::new();
        events.push(meta_process(1, "workers"));
        for (w, &site) in self.site_of.iter().enumerate() {
            events.push(meta_thread(1, w, &format!("worker {w} @ site {site}")));
        }
        let mut links: BTreeSet<(usize, usize)> = BTreeSet::new();
        for r in &self.records {
            if js(r, "type") == "span" {
                let ph = js(r, "phase");
                if ph == "upload" || ph == "return" {
                    links.insert((jf(r, "from") as usize, jf(r, "to") as usize));
                }
            }
        }
        if !links.is_empty() {
            events.push(meta_process(2, "links"));
            for &(f, t) in &links {
                let tid = f * self.nsites + t;
                events.push(meta_thread(2, tid, &format!("link s{f} to s{t}")));
            }
        }
        for r in &self.records {
            match js(r, "type") {
                "span" => {
                    let ph = js(r, "phase");
                    let (pid, tid) = if ph == "upload" || ph == "return" {
                        let f = jf(r, "from") as usize;
                        let t = jf(r, "to") as usize;
                        (2, f * self.nsites + t)
                    } else {
                        (1, jf(r, "worker") as usize)
                    };
                    let t0 = jf(r, "t0");
                    let t1 = jf(r, "t1");
                    events.push(Json::from_pairs(vec![
                        ("ph", Json::str("X")),
                        ("pid", Json::num(pid as f64)),
                        ("tid", Json::num(tid as f64)),
                        ("ts", Json::num(t0 * 1e6)),
                        ("dur", Json::num((t1 - t0) * 1e6)),
                        ("name", Json::str(ph)),
                        ("cat", Json::str("span")),
                        (
                            "args",
                            Json::from_pairs(vec![("id", Json::num(jf(r, "id")))]),
                        ),
                    ]));
                }
                "event" => {
                    let has_worker = r.get("worker").is_some();
                    let tid = if has_worker { jf(r, "worker") } else { 0.0 };
                    let scope = if has_worker { "t" } else { "g" };
                    events.push(Json::from_pairs(vec![
                        ("ph", Json::str("i")),
                        ("pid", Json::num(1.0)),
                        ("tid", Json::num(tid)),
                        ("ts", Json::num(jf(r, "t") * 1e6)),
                        ("s", Json::str(scope)),
                        ("name", Json::str(js(r, "kind"))),
                        ("cat", Json::str("event")),
                        (
                            "args",
                            Json::from_pairs(vec![("id", Json::num(jf(r, "id")))]),
                        ),
                    ]));
                }
                _ => {}
            }
        }
        Json::from_pairs(vec![("traceEvents", Json::Arr(events))]).render()
    }

    /// Write the trace to `path` in the requested format.
    pub fn write(&self, path: &Path, format: TraceFormat) -> Result<()> {
        let text = match format {
            TraceFormat::Jsonl => self.render_jsonl(),
            TraceFormat::Chrome => {
                let mut s = self.render_chrome();
                s.push('\n');
                s
            }
        };
        std::fs::write(path, text)
            .with_context(|| format!("writing trace to {}", path.display()))?;
        Ok(())
    }

    /// Fold the trace into fixed-width windows anchored at t=0.
    /// Spans contribute their overlap with each window (so utilization
    /// and queue depth are exact time averages); `req` records bin by
    /// completion time, drop/evict events by event time; transfer bits
    /// spread proportionally to leg overlap (a zero-duration leg bins
    /// wholly at its start).
    pub fn windows(&self, width: f64) -> WindowSeries {
        let nclasses = qos::class_count();
        let mut series = WindowSeries {
            width,
            workers: self.workers,
            windows: Vec::new(),
        };
        if !width.is_finite() || width <= 0.0 {
            return series;
        }
        let mut horizon = 0.0f64;
        for r in &self.records {
            let t = match js(r, "type") {
                "span" | "req" => jf(r, "t1"),
                "event" => jf(r, "t"),
                _ => 0.0,
            };
            if t > horizon {
                horizon = t;
            }
        }
        if horizon <= 0.0 {
            return series;
        }
        let nwin = (horizon / width).ceil().max(1.0) as usize;
        for i in 0..nwin {
            series.windows.push(WindowStat {
                t0: i as f64 * width,
                t1: (i + 1) as f64 * width,
                served: 0,
                drops: 0,
                class_served: vec![0; nclasses],
                class_missed: vec![0; nclasses],
                util: vec![0.0; self.workers],
                queue_depth: 0.0,
                link_bits: BTreeMap::new(),
            });
        }
        let idx = |t: f64| -> usize { ((t / width) as usize).min(nwin - 1) };
        for r in &self.records {
            match js(r, "type") {
                "req" => {
                    let w = &mut series.windows[idx(jf(r, "t1"))];
                    let class = (jf(r, "qos") as usize).min(nclasses - 1);
                    w.served += 1;
                    w.class_served[class] += 1;
                    if jf(r, "missed") > 0.0 {
                        w.class_missed[class] += 1;
                    }
                }
                "event" => {
                    let kind = js(r, "kind");
                    if kind == "drop"
                        || kind == "evict"
                        || kind == "retry-exhausted"
                    {
                        series.windows[idx(jf(r, "t"))].drops += 1;
                    }
                }
                "span" => {
                    let ph = js(r, "phase");
                    let lo = jf(r, "t0");
                    let hi = jf(r, "t1");
                    let dur = hi - lo;
                    let is_link = ph == "upload" || ph == "return";
                    if dur <= 0.0 {
                        if is_link {
                            let key = (jf(r, "from") as usize, jf(r, "to") as usize);
                            let w = &mut series.windows[idx(lo)];
                            *w.link_bits.entry(key).or_insert(0.0) += jf(r, "bits");
                        }
                        continue;
                    }
                    for wi in idx(lo)..=idx(hi) {
                        let w = &mut series.windows[wi];
                        let ov = hi.min(w.t1) - lo.max(w.t0);
                        if ov <= 0.0 {
                            continue;
                        }
                        match ph {
                            "gen" | "cold" => {
                                let worker = (jf(r, "worker") as usize)
                                    .min(self.workers.saturating_sub(1));
                                w.util[worker] += ov;
                            }
                            "queue" => w.queue_depth += ov,
                            "upload" | "return" => {
                                let key =
                                    (jf(r, "from") as usize, jf(r, "to") as usize);
                                *w.link_bits.entry(key).or_insert(0.0) +=
                                    jf(r, "bits") * ov / dur;
                            }
                            _ => {}
                        }
                    }
                }
                _ => {}
            }
        }
        for w in &mut series.windows {
            for u in &mut w.util {
                *u /= width;
            }
            w.queue_depth /= width;
        }
        series
    }
}

/// One window of the folded time-series.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowStat {
    pub t0: f64,
    pub t1: f64,
    /// Completions whose finish time fell in this window.
    pub served: usize,
    /// Admission drops + priority evictions in this window.
    pub drops: usize,
    pub class_served: Vec<usize>,
    pub class_missed: Vec<usize>,
    /// Per-worker busy fraction (gen + cold overlap / width).
    pub util: Vec<f64>,
    /// Time-averaged parked-queue depth over the window.
    pub queue_depth: f64,
    /// Bits in flight per (from, to) link, overlap-weighted.
    pub link_bits: BTreeMap<(usize, usize), f64>,
}

impl WindowStat {
    pub fn mean_util(&self) -> f64 {
        if self.util.is_empty() {
            return 0.0;
        }
        let mut s = 0.0;
        for &u in &self.util {
            s += u;
        }
        s / self.util.len() as f64
    }

    pub fn missed(&self) -> usize {
        let mut n = 0;
        for &m in &self.class_missed {
            n += m;
        }
        n
    }

    pub fn total_bits(&self) -> f64 {
        let mut s = 0.0;
        for b in self.link_bits.values() {
            s += *b;
        }
        s
    }
}

/// The full windowed series: `serve` prints it as a table and
/// `--window-csv` writes `render_csv()` for downstream experiment
/// tooling.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowSeries {
    pub width: f64,
    pub workers: usize,
    pub windows: Vec<WindowStat>,
}

impl WindowSeries {
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// CSV with one row per window. Columns: window bounds, served,
    /// throughput, drops, queue depth, per-worker utilization,
    /// per-class served/missed, and per-link bits (union of links
    /// observed in any window, sorted).
    pub fn render_csv(&self) -> String {
        let mut links: BTreeSet<(usize, usize)> = BTreeSet::new();
        for w in &self.windows {
            for &k in w.link_bits.keys() {
                links.insert(k);
            }
        }
        let mut out = String::new();
        out.push_str("window,t0,t1,served,req_per_s,drops,queue_depth");
        for w in 0..self.workers {
            out.push_str(&format!(",util_w{w}"));
        }
        for c in 0..qos::class_count() {
            let name = qos::class(c).name;
            out.push_str(&format!(",{name}_served,{name}_missed"));
        }
        for &(f, t) in &links {
            out.push_str(&format!(",bits_s{f}_s{t}"));
        }
        out.push('\n');
        for (i, w) in self.windows.iter().enumerate() {
            let rate = if self.width > 0.0 {
                w.served as f64 / self.width
            } else {
                0.0
            };
            out.push_str(&format!(
                "{i},{:.3},{:.3},{},{:.6},{},{:.6}",
                w.t0, w.t1, w.served, rate, w.drops, w.queue_depth
            ));
            for u in &w.util {
                out.push_str(&format!(",{u:.6}"));
            }
            for c in 0..w.class_served.len() {
                out.push_str(&format!(
                    ",{},{}",
                    w.class_served[c], w.class_missed[c]
                ));
            }
            for &k in &links {
                let bits = w.link_bits.get(&k).copied().unwrap_or(0.0);
                out.push_str(&format!(",{bits:.3}"));
            }
            out.push('\n');
        }
        out
    }
}

fn meta_process(pid: usize, name: &str) -> Json {
    Json::from_pairs(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("name", Json::str("process_name")),
        ("args", Json::from_pairs(vec![("name", Json::str(name))])),
    ])
}

fn meta_thread(pid: usize, tid: usize, name: &str) -> Json {
    Json::from_pairs(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("name", Json::str("thread_name")),
        ("args", Json::from_pairs(vec![("name", Json::str(name))])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::corpus::PromptDesc;

    fn req(id: u64, t: f64) -> Request {
        Request {
            id,
            prompt: PromptDesc::default(),
            z: 8,
            model: 0,
            origin: 0,
            qos: 0,
            deadline: f64::INFINITY,
            submitted_at: t,
        }
    }

    fn resp(r: &Request, worker: usize, latency: f64, gen: f64) -> Response {
        Response {
            id: r.id,
            worker,
            z: r.z,
            model: r.model,
            latency,
            queue_wait: latency - gen,
            gen_time: gen,
            trans_time: 0.0,
            checksum: 0.0,
            qos: r.qos,
            deadline: r.deadline,
            demanded_z: r.z,
            demanded_model: r.model,
        }
    }

    /// Drive one request through the hook sequence by hand.
    fn one_request_trace() -> TraceLog {
        let mut t = Tracer::new(2, None);
        let r = req(7, 1.0);
        t.admit(&r, r.z, r.model, 1.0);
        t.dispatch(&r, 1, 0.0, 4.0, 0.0, 0.5);
        // queue [1.0, 2.5], cold [2.5, 3.0], gen [3.0, 7.0]
        t.start(r.id, 3.0);
        t.complete(&resp(&r, 1, 6.0, 4.0), 7.0);
        t.finish()
    }

    #[test]
    fn fnv1a_known_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn trace_format_parses() {
        assert_eq!(TraceFormat::parse("jsonl").unwrap(), TraceFormat::Jsonl);
        assert_eq!(TraceFormat::parse("chrome").unwrap(), TraceFormat::Chrome);
        assert!(TraceFormat::parse("protobuf").is_err());
        assert_eq!(TraceFormat::default().label(), "jsonl");
    }

    #[test]
    fn spans_telescope_to_latency() {
        let log = one_request_trace();
        assert_eq!(log.count_type("meta"), 1);
        assert_eq!(log.count_type("req"), 1);
        // no network -> no upload/return spans
        assert_eq!(log.count_spans("upload"), 0);
        assert_eq!(log.count_spans("return"), 0);
        assert_eq!(log.count_spans("queue"), 1);
        assert_eq!(log.count_spans("cold"), 1);
        assert_eq!(log.count_spans("gen"), 1);
        let mut sum = 0.0;
        for r in log.records() {
            if js(r, "type") == "span" {
                sum += jf(r, "t1") - jf(r, "t0");
            }
        }
        assert!((sum - 6.0).abs() < 1e-12, "span sum {sum} != latency 6");
    }

    #[test]
    fn jsonl_is_deterministic_and_hash_matches() {
        let a = one_request_trace();
        let b = one_request_trace();
        assert_eq!(a.render_jsonl(), b.render_jsonl());
        assert_eq!(a.hash(), b.hash());
        assert_eq!(a.hash(), fnv1a(a.render_jsonl().as_bytes()));
        // every line is valid standalone JSON
        for line in a.render_jsonl().lines() {
            Json::parse(line).expect("jsonl line parses");
        }
    }

    #[test]
    fn chrome_render_is_valid_json_with_tracks() {
        let log = one_request_trace();
        let doc = Json::parse(&log.render_chrome()).expect("chrome parses");
        let events = doc.req("traceEvents").unwrap().as_arr().unwrap();
        // 1 process meta + 2 worker threads + 3 X spans
        let mut x = 0;
        let mut m = 0;
        for e in events {
            match js(e, "ph") {
                "X" => x += 1,
                "M" => m += 1,
                _ => {}
            }
        }
        assert_eq!(x, 3);
        assert_eq!(m, 3);
    }

    #[test]
    fn drop_and_evict_events_are_counted() {
        let mut t = Tracer::new(1, None);
        let a = req(1, 0.0);
        t.admit(&a, a.z, a.model, 0.0);
        let b = req(2, 0.5);
        t.drop_req(0.5, &b);
        let victim = EdfJob {
            ready_at: 0.0,
            req: a,
            up: 0.0,
            gen: 1.0,
            down: 0.0,
            load_delay: 0.0,
            demanded_z: a.z,
            demanded_model: a.model,
        };
        let c = req(3, 0.6);
        t.evict(0.6, 0, &victim, &c);
        let log = t.finish();
        assert_eq!(log.count_events("drop"), 1);
        assert_eq!(log.count_events("evict"), 1);
        // the evicted request never completes: no spans, no req record
        assert_eq!(log.count_type("req"), 0);
        assert_eq!(log.count_type("span"), 0);
    }

    #[test]
    fn degrade_event_fires_on_mutated_admission() {
        let mut t = Tracer::new(1, None);
        let mut r = req(1, 0.0);
        r.z = 8;
        t.admit(&r, 15, r.model, 0.0); // demanded 15, served 8
        let log = t.finish();
        assert_eq!(log.count_events("degrade"), 1);
    }

    #[test]
    fn fault_hooks_emit_events_and_reset_killed_dispatch_state() {
        let mut t = Tracer::new(2, None);
        t.site_down(10.0, 0);
        t.link_change(10.0, 0, 1, 8.0);
        // request dispatched to worker 1 then killed there at t=12
        let r = req(4, 9.0);
        t.admit(&r, r.z, r.model, 9.0);
        t.dispatch(&r, 1, 0.0, 4.0, 0.0, 0.5);
        t.start(r.id, 10.0);
        t.kill(12.0, r.id, 1);
        t.retry(12.5, r.id, 1);
        // the retry serves on worker 0; spans must describe *this*
        // dispatch (gen on worker 0), not the killed one
        t.dispatch(&r, 0, 0.0, 4.0, 0.0, 0.0);
        t.start(r.id, 13.0);
        t.complete(&resp(&r, 0, 8.0, 4.0), 17.0);
        // a second request exhausts its budget
        let e = req(5, 9.5);
        t.admit(&e, e.z, e.model, 9.5);
        t.kill(12.0, e.id, 1);
        t.retry(12.5, e.id, 1);
        t.exhaust(14.0, e.id);
        t.site_up(15.0, 0);
        t.link_change(15.0, 0, 1, 1.0);
        let log = t.finish();
        for kind in [
            "site-down",
            "site-up",
            "link-degrade",
            "link-restore",
            "retry-exhausted",
        ] {
            assert_eq!(log.count_events(kind), 1, "{kind}");
        }
        assert_eq!(log.count_events("kill"), 2);
        assert_eq!(log.count_events("retry"), 2);
        // the exhausted request left no req record; the recovered one
        // completed with its gen span on the retry worker
        assert_eq!(log.count_type("req"), 1);
        for rec in log.records() {
            if js(rec, "type") == "span" && js(rec, "phase") == "gen" {
                assert_eq!(jf(rec, "worker"), 0.0, "span from killed leg");
            }
        }
        // an exhausted loss bins as a drop in the windowed series
        let series = log.windows(20.0);
        assert_eq!(series.windows[0].drops, 1);
        assert_eq!(series.windows[0].served, 1);
    }

    #[test]
    fn windows_bin_spans_and_completions() {
        let log = one_request_trace();
        // horizon 7.0, width 2.0 -> 4 windows
        let series = log.windows(2.0);
        assert_eq!(series.windows.len(), 4);
        // completion at t=7.0 lands in the last window
        assert_eq!(series.windows[3].served, 1);
        let mut total_served = 0;
        for w in &series.windows {
            total_served += w.served;
        }
        assert_eq!(total_served, 1);
        // gen [3,7] on worker 1: window [2,4] holds 1s -> util 0.5,
        // windows [4,6] full -> 1.0 (plus cold [2.5,3.0] in [2,4])
        assert!((series.windows[2].util[1] - 1.0).abs() < 1e-12);
        let w1 = &series.windows[1];
        assert!((w1.util[1] - (1.0 + 0.5) / 2.0).abs() < 1e-12);
        // worker 0 never busy
        for w in &series.windows {
            assert_eq!(w.util[0], 0.0);
        }
        // queue span [1.0, 2.5]: 1s in window 0, 0.5s in window 1
        assert!((series.windows[0].queue_depth - 0.5).abs() < 1e-12);
        assert!((w1.queue_depth - 0.25).abs() < 1e-12);
        // CSV renders one line per window + header
        let csv = series.render_csv();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("window,t0,t1,served"));
    }

    #[test]
    fn windows_zero_width_or_empty_trace_are_empty() {
        let log = one_request_trace();
        assert!(log.windows(0.0).is_empty());
        assert!(log.windows(-1.0).is_empty());
        let empty = Tracer::new(1, None).finish();
        assert!(empty.windows(10.0).is_empty());
    }
}
