//! DEdgeAI — the serving prototype (§VI).
//!
//! The paper's testbed is five Jetson AGX Orin devices on a Gigabit LAN
//! serving reSD3-m. Here (DESIGN.md §2 substitutions) the same
//! architecture runs as threads in one process:
//!
//! - [`worker`]: one thread per "Jetson", owning its own PJRT client
//!   and executing the AOT generation model (`genmodel_*` HLO, Pallas
//!   kernel inside) for `z_n` denoising steps per request;
//! - [`router`]: the dispatcher implementing the scheduling policy
//!   (least-loaded, round-robin, or the LADN diffusion actor via the
//!   B=5 artifacts — the paper's scheduler-per-device);
//! - [`clock`]: real wallclock or the calibrated virtual Jetson clock
//!   used by Table V;
//! - [`events`]: the virtual-time discrete-event queue driving
//!   open-loop serving — arrivals and worker completions interleave on
//!   one clock, so `Router::complete` fires at the correct virtual
//!   timestamp and pending-load estimates drain under live traffic;
//! - [`arrivals`]: open-loop arrival processes (Poisson, bursty MMPP,
//!   diurnal ramp; the Table V batch protocol is the special case) and
//!   per-request quality-demand distributions (`--z-dist`);
//! - [`platforms`]: the five commercial-platform latency/price models
//!   of Table V; [`models`]: the SD3-m vs reSD3-m memory registry;
//! - [`placement`]: model placement & cache-aware serving — the
//!   variant catalog (reSD3-m / SD3-medium / distilled turbo) with
//!   VRAM footprints from the §VI.C accounting, per-worker VRAM
//!   budgets over LRU model caches charging cold-load delays in
//!   virtual time, per-request model demand (`--model-dist`), and the
//!   slow-timescale re-placement hook (after arXiv:2411.01458);
//! - [`network`]: the inter-edge network — N edge sites with a
//!   bandwidth/latency matrix (named profiles: uniform/lan/wan/star/
//!   degraded, `--bw-matrix` overrides), workers pinned to sites,
//!   requests originating at seeded sites, and prompt-upload /
//!   image-return legs charged in virtual time so service delay
//!   decomposes into transmission + queuing + computation;
//! - [`qos`]: QoS classes — deadline budgets, priority tiers, and
//!   willingness-to-degrade drawn as a sixth seeded request stream
//!   (`--qos-mix`), driving earliest-deadline-first dispatch,
//!   priority-aware admission, and deadline-pressed quality
//!   degradation (serve z=15 as z=8 or swap to the distilled turbo);
//! - [`faults`]: deterministic fault injection — scripted/stochastic
//!   site failures and link degradation on the virtual clock, with
//!   kill/retry/re-dispatch semantics on the serving path (see
//!   `docs/faults.md`);
//! - [`trace`]: deterministic observability — per-request virtual-time
//!   spans and discrete events behind `--trace-out`, windowed
//!   time-series (`--window`), byte-identical across double runs and
//!   both engines (see `docs/observability.md`);
//! - [`decisions`]: decision-level observability — per-dispatch
//!   candidate score tables behind `--decisions-out`, joined with
//!   realized delays into calibration and hindsight-regret books
//!   (the learn-to-serve replay substrate; see
//!   `docs/observability.md`);
//! - [`corpus`]: the synthetic caption corpus standing in for
//!   Flickr8k (hot paths carry a `Copy` [`corpus::PromptDesc`]; text
//!   is rehydrated only on the real-time PJRT path);
//! - [`source`]: the lazy request stream — arrival/caption/z/model
//!   draws synthesised per request, so open-loop runs hold
//!   O(in-flight) state instead of materialising the whole trace.
//!
//! Serving entry points: `DEdgeAi::run_batch` (Table V closed batch,
//! bit-stable), `DEdgeAi::run_events` (open loop on the event engine),
//! `DEdgeAi::run_real` (threads + PJRT). The `serve-sweep` experiment
//! (`sim::experiments`) fans (arrival rate × scheduler × fleet size)
//! grids of open-loop runs over the parallel executor.

pub mod arrivals;
pub mod clock;
pub mod corpus;
pub mod decisions;
pub mod events;
pub mod faults;
pub mod message;
pub mod metrics;
pub mod models;
pub mod network;
pub mod placement;
pub mod platforms;
pub mod qos;
pub mod router;
pub mod service;
pub mod source;
pub mod trace;
pub mod worker;

pub use arrivals::{ArrivalProcess, ZDist};
pub use corpus::PromptDesc;
pub use decisions::{DecisionBook, DecisionLog};
pub use events::{Event, EventQueue};
pub use faults::{FaultPlan, FaultRuntime};
pub use message::{Request, Response};
pub use source::{OriginDist, RequestSource};
pub use metrics::ServeMetrics;
pub use network::{NetOptions, Network, Topology};
pub use placement::{Catalog, ModelDist, Placement};
pub use qos::{QosClass, QosMix};
pub use service::{serve_and_report, DEdgeAi, ServeOptions};
pub use trace::{TraceFormat, TraceLog, Tracer};
