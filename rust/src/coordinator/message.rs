//! Wire types between router and workers.

use super::corpus::PromptDesc;

/// One text-to-image request.
///
/// `Copy`: the serving hot path moves requests through the event
/// engine by value with no heap allocation — the caption travels as a
/// [`PromptDesc`] (template indices + derivable byte length), and only
/// the real-time PJRT path rehydrates the text, at submit time.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    /// Caption descriptor (`prompt.len_bytes()` for the LAN/state
    /// models, `prompt.render()` for actual generation).
    pub prompt: PromptDesc,
    /// Generation-quality demand z_n (denoising steps).
    pub z: usize,
    /// Model-variant demand: index into the placement
    /// [`Catalog`](super::placement::Catalog) (0 = reSD3-m, the
    /// paper's default deployment). Ignored when placement is off.
    pub model: usize,
    /// Origin edge site: where the request entered the network
    /// (index into the [`Topology`](super::network::Topology)).
    /// Always 0 when the network subsystem is off (single site).
    pub origin: usize,
    /// QoS class id (index into the static
    /// [`qos`](super::qos) registry). [`qos::BEST_EFFORT`]
    /// (0) when no `--qos-mix` is active — the pre-QoS default.
    pub qos: usize,
    /// Absolute deadline on the serving clock
    /// (`submitted_at + class.deadline_s`); `f64::INFINITY` for the
    /// best-effort default, so deadline math is inert when QoS is off.
    pub deadline: f64,
    /// Submission time (seconds on the serving clock).
    pub submitted_at: f64,
}

/// Completed generation.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    pub id: u64,
    pub worker: usize,
    /// The quality demand actually served — routers must drain pending
    /// load by this, not by any global default (loads are wrong
    /// otherwise whenever z is heterogeneous).
    pub z: usize,
    /// The model variant actually served (catalog index).
    pub model: usize,
    /// End-to-end latency (submission -> result), seconds.
    pub latency: f64,
    /// Time spent in the worker queue, seconds.
    pub queue_wait: f64,
    /// Pure generation time, seconds.
    pub gen_time: f64,
    /// Transmission time (prompt upload + image return), seconds.
    /// With `queue_wait` and `gen_time` this decomposes the paper's
    /// service delay: latency = transmission + queuing + computation.
    pub trans_time: f64,
    /// Checksum of the produced latent (integrity check; proves the
    /// compute actually ran through PJRT).
    pub checksum: f32,
    /// QoS class id carried through from the request.
    pub qos: usize,
    /// Absolute deadline carried through from the request; metrics
    /// compare it against the completion time for the miss ledger.
    pub deadline: f64,
    /// The quality the *request* demanded. `z < demanded_z` means the
    /// deadline-pressed degradation stage reduced denoising steps.
    pub demanded_z: usize,
    /// The model the *request* demanded. `model != demanded_model`
    /// means degradation rerouted to the distilled variant.
    pub demanded_model: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_fields() {
        let r = Request {
            id: 7,
            prompt: PromptDesc::from_indices(0, 0, 0),
            z: 15,
            model: 0,
            origin: 0,
            qos: 0,
            deadline: f64::INFINITY,
            submitted_at: 1.5,
        };
        assert_eq!(r.id, 7);
        assert_eq!(r.z, 15);
        assert_eq!(r.model, 0);
        assert_eq!(r.origin, 0);
        assert_eq!(r.qos, 0);
        assert!(r.deadline.is_infinite());
        assert!(r.prompt.len_bytes() > 0);
        let resp = Response {
            id: r.id,
            worker: 2,
            z: r.z,
            model: r.model,
            latency: 18.3,
            queue_wait: 0.0,
            gen_time: 18.3,
            trans_time: 0.0,
            checksum: 0.5,
            qos: r.qos,
            deadline: r.deadline,
            demanded_z: r.z,
            demanded_model: r.model,
        };
        assert_eq!(resp.id, r.id);
        assert_eq!(resp.z, 15);
        assert_eq!(resp.model, 0);
        assert_eq!(resp.demanded_z, resp.z);
        assert_eq!(resp.demanded_model, resp.model);
    }
}
