//! Serving metrics: latency distribution, throughput, per-worker load,
//! the steady-state measures used by the open-loop engine (p99,
//! time-in-system, windowed throughput, per-worker utilization), and
//! the network subsystem's delay decomposition (transmission + queuing
//! + computation = time-in-system) with per-link traffic accounting.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::util::rng::RngAudit;
use crate::util::stats::{percentile_sorted, Welford};

use super::decisions::DecisionBook;
use super::message::Response;
use super::trace::TraceLog;

/// Aggregate traffic on one directed site-to-site link.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkStat {
    /// Payload bits moved over the link.
    pub bits: f64,
    /// Seconds the link spent busy (includes per-transfer RTT).
    pub secs: f64,
    /// Completed transfer legs.
    pub transfers: u64,
}

/// Per-QoS-class serving book (QoS runs only): completions, deadline
/// misses, the degradation ledger, and per-class latency quantiles —
/// the per-class mirror of the per-link [`LinkStat`] books.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassStat {
    /// Completions in this class.
    pub count: u64,
    /// Completions that landed after their absolute deadline.
    pub misses: u64,
    /// Completions served below their demanded z (step reduction).
    pub degraded: u64,
    /// Completions served on a different model than demanded
    /// (rerouted to the distilled variant under deadline pressure).
    pub rerouted: u64,
    latencies: Vec<f64>,
}

impl ClassStat {
    fn quantile(&self, p: f64) -> f64 {
        let mut v = self.latencies.clone();
        v.sort_unstable_by(f64::total_cmp);
        percentile_sorted(&v, p)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(99.0)
    }

    /// Fraction of this class's completions that missed their deadline.
    pub fn miss_rate(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.misses as f64 / self.count as f64
        }
    }

    /// Recorded latencies in completion order (for bitwise compares).
    pub fn latencies(&self) -> &[f64] {
        &self.latencies
    }
}

/// Fault-injection ledger (fault runs only): what the failure plan
/// cost and how the serving path absorbed it. The conservation
/// invariant the fault suite asserts reads from here:
/// `served + dropped + exhausted_retries == arrivals`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultLedger {
    /// Running or parked jobs killed by a site failure.
    pub kills: u64,
    /// Re-dispatch attempts actually made for killed jobs.
    pub retries: u64,
    /// Killed-at-least-once requests that were eventually served
    /// (lost-then-recovered work).
    pub recovered: u64,
    /// Killed requests abandoned after the retry budget ran out.
    pub exhausted_retries: u64,
    /// `SiteDown` edges that took a site from up to down.
    pub site_down_events: u64,
    /// `SiteUp` edges that brought a site back.
    pub site_up_events: u64,
    /// Link degrade/restore edges applied to the network overlay.
    pub link_events: u64,
    /// Virtual seconds each worker spent down.
    pub downtime_s: Vec<f64>,
    /// Virtual time of the last site recovery (`None`: no recovery
    /// happened — an `Option` so bitwise compares never meet a NaN).
    pub last_recovery_t: Option<f64>,
}

impl FaultLedger {
    fn new(workers: usize) -> Self {
        Self { downtime_s: vec![0.0; workers], ..Self::default() }
    }
}

#[derive(Clone, Debug)]
pub struct ServeMetrics {
    latencies: Vec<f64>,
    /// Lazily sorted copy of `latencies`, shared by every quantile
    /// query: `median/p95/p99` used to pay a full clone+sort *each*,
    /// i.e. three sorts per report. Invalidated on `record`.
    sorted_latencies: RefCell<Option<Vec<f64>>>,
    /// Completion timestamps on the serving clock (for windowed rates).
    completions: Vec<f64>,
    queue_waits: Welford,
    gen_times: Welford,
    /// Transmission time (prompt upload + image return) per request.
    trans_times: Welford,
    /// Max relative residual of the per-request decomposition identity
    /// latency = queue_wait + gen_time + trans_time — asserted ≈0 by
    /// the network test suite.
    decomp_err: f64,
    /// Per-link traffic (network runs only): (from, to) → stats.
    links: BTreeMap<(usize, usize), LinkStat>,
    /// Per-QoS-class books (populated only when a QoS run arms them
    /// via [`set_qos_active`](Self::set_qos_active); empty otherwise
    /// so the pre-QoS metrics surface is untouched).
    classes: BTreeMap<usize, ClassStat>,
    /// Whether this run carries QoS semantics (a `--qos-mix` was set).
    qos_active: bool,
    /// Fault-injection ledger (populated only when a fault run arms it
    /// via [`set_faults_active`](Self::set_faults_active); all-zero
    /// otherwise so the pre-fault metrics surface is untouched).
    faults: FaultLedger,
    /// Whether this run carries fault-injection semantics.
    faults_active: bool,
    per_worker: Vec<u64>,
    /// Seconds each worker spent generating (for utilization).
    busy: Vec<f64>,
    first_submit: f64,
    last_complete: f64,
    /// Model-cache accounting (placement-aware serving).
    cache_hits: u64,
    cache_misses: u64,
    evictions: u64,
    /// Total virtual seconds spent cold-loading model weights.
    cold_load_s: f64,
    /// Requests rejected by admission control (`--queue-cap`).
    dropped: u64,
    /// High-water mark of the event queue (streaming engine: bounded
    /// by in-flight work, not total requests — the O(in-flight) claim
    /// a guard test asserts).
    queue_peak: usize,
    /// High-water mark of admitted-but-incomplete requests.
    in_flight_peak: usize,
    /// Per-stream RNG draw counts, recorded by the virtual-clock
    /// engines at drain time (empty on the real-time path). The
    /// `verify-determinism` harness compares it bitwise across runs.
    rng_audit: RngAudit,
    /// The sealed observability recording (`--trace-out`/`--window`
    /// runs only; `None` keeps the trace-free surface untouched).
    trace: Option<TraceLog>,
    /// The sealed decision recording (`--decisions-out` runs only;
    /// `None` keeps the decisions-free surface untouched).
    decisions: Option<DecisionBook>,
}

impl ServeMetrics {
    pub fn new(workers: usize) -> Self {
        Self {
            latencies: Vec::new(),
            sorted_latencies: RefCell::new(None),
            completions: Vec::new(),
            queue_waits: Welford::new(),
            gen_times: Welford::new(),
            trans_times: Welford::new(),
            decomp_err: 0.0,
            links: BTreeMap::new(),
            classes: BTreeMap::new(),
            qos_active: false,
            faults: FaultLedger::new(workers),
            faults_active: false,
            per_worker: vec![0; workers],
            busy: vec![0.0; workers],
            first_submit: f64::INFINITY,
            last_complete: 0.0,
            cache_hits: 0,
            cache_misses: 0,
            evictions: 0,
            cold_load_s: 0.0,
            dropped: 0,
            queue_peak: 0,
            in_flight_peak: 0,
            rng_audit: RngAudit::new(),
            trace: None,
            decisions: None,
        }
    }

    /// Quantile over the latency distribution via the shared
    /// sort-once cache. NaN latencies are a recording bug — asserted
    /// here (debug) because `total_cmp` would otherwise order them
    /// silently instead of panicking like the old `partial_cmp` sort.
    fn latency_quantile(&self, p: f64) -> f64 {
        let mut cache = self.sorted_latencies.borrow_mut();
        let sorted = cache.get_or_insert_with(|| {
            debug_assert!(
                self.latencies.iter().all(|x| !x.is_nan()),
                "NaN latency recorded"
            );
            let mut v = self.latencies.clone();
            v.sort_unstable_by(f64::total_cmp);
            v
        });
        percentile_sorted(sorted, p)
    }

    /// Record a completion. A worker index outside the fleet is a hard
    /// error: silently dropping it would mask router bugs (a policy
    /// that picks a phantom worker would look *better*, not broken).
    pub fn record(&mut self, resp: &Response, completed_at: f64) {
        assert!(
            resp.worker < self.per_worker.len(),
            "ServeMetrics::record: worker {} out of range for a {}-worker \
             fleet (router bug)",
            resp.worker,
            self.per_worker.len()
        );
        self.latencies.push(resp.latency);
        self.sorted_latencies.borrow_mut().take();
        self.completions.push(completed_at);
        self.queue_waits.push(resp.queue_wait);
        self.gen_times.push(resp.gen_time);
        self.trans_times.push(resp.trans_time);
        // delay-decomposition residual (float association error only)
        let residual = (resp.latency
            - (resp.queue_wait + resp.gen_time + resp.trans_time))
            .abs()
            / resp.latency.abs().max(1.0);
        self.decomp_err = self.decomp_err.max(residual);
        self.per_worker[resp.worker] += 1;
        self.busy[resp.worker] += resp.gen_time;
        self.first_submit = self
            .first_submit
            .min(completed_at - resp.latency);
        self.last_complete = self.last_complete.max(completed_at);
        if self.qos_active {
            let cs = self.classes.entry(resp.qos).or_default();
            cs.count += 1;
            cs.latencies.push(resp.latency);
            if completed_at > resp.deadline {
                cs.misses += 1;
            }
            if resp.z < resp.demanded_z {
                cs.degraded += 1;
            }
            if resp.model != resp.demanded_model {
                cs.rerouted += 1;
            }
        }
    }

    /// Arm the per-class books: QoS runs call this once before
    /// serving. Left unarmed, `record` skips class accounting entirely
    /// so non-QoS metrics stay structurally identical to PR 6.
    pub fn set_qos_active(&mut self) {
        self.qos_active = true;
    }

    /// Whether the per-class books are armed.
    pub fn qos_active(&self) -> bool {
        self.qos_active
    }

    /// Per-class serving books, keyed by class id (empty unless a QoS
    /// run armed them).
    pub fn class_stats(&self) -> &BTreeMap<usize, ClassStat> {
        &self.classes
    }

    /// Deadline-miss fraction across every class (0 when QoS is off
    /// or nothing completed).
    pub fn deadline_miss_rate(&self) -> f64 {
        let (mut misses, mut count) = (0u64, 0u64);
        for cs in self.classes.values() {
            misses += cs.misses;
            count += cs.count;
        }
        if count == 0 {
            0.0
        } else {
            misses as f64 / count as f64
        }
    }

    /// Completions served degraded (fewer steps) or rerouted (swapped
    /// model), across all classes.
    pub fn degradations(&self) -> (u64, u64) {
        let mut degraded = 0;
        let mut rerouted = 0;
        for cs in self.classes.values() {
            degraded += cs.degraded;
            rerouted += cs.rerouted;
        }
        (degraded, rerouted)
    }

    /// Arm the fault ledger: fault-injection runs call this once
    /// before serving. Left unarmed, every `record_fault_*` call is a
    /// no-op so faults-off metrics stay structurally identical to the
    /// pre-fault engine.
    pub fn set_faults_active(&mut self) {
        self.faults_active = true;
    }

    /// Whether the fault ledger is armed.
    pub fn faults_active(&self) -> bool {
        self.faults_active
    }

    /// The fault-injection ledger (all-zero unless a fault run armed
    /// it).
    pub fn faults(&self) -> &FaultLedger {
        &self.faults
    }

    /// Book one killed job (running or parked on a failed site).
    pub fn record_kill(&mut self) {
        if self.faults_active {
            self.faults.kills += 1;
        }
    }

    /// Book one re-dispatch attempt for a killed request.
    pub fn record_retry(&mut self) {
        if self.faults_active {
            self.faults.retries += 1;
        }
    }

    /// Book one killed-then-served request (recovered work).
    pub fn record_recovered(&mut self) {
        if self.faults_active {
            self.faults.recovered += 1;
        }
    }

    /// Book one request abandoned after its retry budget ran out.
    pub fn record_retry_exhausted(&mut self) {
        if self.faults_active {
            self.faults.exhausted_retries += 1;
        }
    }

    /// Book one up→down site edge.
    pub fn record_site_down(&mut self) {
        if self.faults_active {
            self.faults.site_down_events += 1;
        }
    }

    /// Book one down→up site edge at virtual time `t` (also the
    /// reference point for [`drain_after_recovery_s`]
    /// (Self::drain_after_recovery_s)).
    pub fn record_site_up(&mut self, t: f64) {
        if self.faults_active {
            self.faults.site_up_events += 1;
            self.faults.last_recovery_t = Some(t);
        }
    }

    /// Book one link degrade or restore edge.
    pub fn record_link_event(&mut self) {
        if self.faults_active {
            self.faults.link_events += 1;
        }
    }

    /// Book `secs` of downtime against `worker` (called at the
    /// worker's recovery, or at drain for a site still down).
    pub fn record_downtime(&mut self, worker: usize, secs: f64) {
        if self.faults_active {
            if let Some(d) = self.faults.downtime_s.get_mut(worker) {
                *d += secs;
            }
        }
    }

    /// Per-worker availability over the makespan: `1 − downtime /
    /// makespan`, clamped to `[0, 1]`; a fleet with no makespan (or an
    /// unarmed ledger) reads fully available.
    pub fn availability(&self) -> Vec<f64> {
        let m = self.makespan();
        self.faults
            .downtime_s
            .iter()
            .map(|&d| {
                if m > 0.0 {
                    (1.0 - d / m).clamp(0.0, 1.0)
                } else {
                    1.0
                }
            })
            .collect()
    }

    /// Mean per-worker availability (1.0 when faults never armed).
    pub fn mean_availability(&self) -> f64 {
        let a = self.availability();
        if a.is_empty() {
            1.0
        } else {
            crate::util::stats::mean(&a)
        }
    }

    /// Virtual seconds between the last site recovery and the last
    /// completion — how long the backlog took to drain after the
    /// final failure cleared. Zero when no recovery happened.
    pub fn drain_after_recovery_s(&self) -> f64 {
        match self.faults.last_recovery_t {
            Some(t) => (self.last_complete - t).max(0.0),
            None => 0.0,
        }
    }

    /// Record one dispatch's model-cache outcome: a warm hit or a cold
    /// miss with however many evictions the load forced.
    pub fn record_cache(&mut self, hit: bool, evictions: u64) {
        if hit {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
        }
        self.evictions += evictions;
    }

    /// Book a completed cold load's delay (charged in virtual time).
    /// The load occupied the worker, so it also counts toward that
    /// worker's busy time — utilization reports occupancy, not just
    /// generation, under cache churn.
    pub fn record_cold_load_on(&mut self, worker: usize, delay_s: f64) {
        self.cold_load_s += delay_s;
        if let Some(b) = self.busy.get_mut(worker) {
            *b += delay_s;
        }
    }

    /// Count evictions that happened outside a dispatch miss (the
    /// slow-timescale re-placement loads).
    pub fn record_evictions(&mut self, n: u64) {
        self.evictions += n;
    }

    /// Record one request rejected by admission control.
    pub fn record_drop(&mut self) {
        self.dropped += 1;
    }

    /// Book one completed inter-site transfer leg into the per-link
    /// accounting (the engine fires this from `Event::TransferDone`).
    pub fn record_transfer(&mut self, from: usize, to: usize, bits: f64, secs: f64) {
        let st = self.links.entry((from, to)).or_default();
        st.bits += bits;
        st.secs += secs;
        st.transfers += 1;
    }

    /// Per-link traffic totals (empty when the network subsystem was
    /// off), keyed by directed (from, to) site pair.
    pub fn link_stats(&self) -> &BTreeMap<(usize, usize), LinkStat> {
        &self.links
    }

    /// Mean transmission time (prompt upload + image return), seconds.
    pub fn mean_trans_time(&self) -> f64 {
        self.trans_times.mean()
    }

    /// Max relative residual of latency = transmission + queuing +
    /// computation across all recorded requests (≈0 up to float
    /// association error; the network suite asserts it).
    pub fn decomposition_error(&self) -> f64 {
        self.decomp_err
    }

    /// Note the engine's current event-queue length and in-flight
    /// count; keeps the high-water marks that certify the streaming
    /// engine's O(in-flight) footprint.
    pub fn note_queue_depth(&mut self, queue_len: usize, in_flight: usize) {
        self.queue_peak = self.queue_peak.max(queue_len);
        self.in_flight_peak = self.in_flight_peak.max(in_flight);
    }

    /// Event-queue high-water mark over the run (0 for engines that
    /// never report depth, e.g. the closed batch loop).
    pub fn queue_peak(&self) -> usize {
        self.queue_peak
    }

    /// High-water mark of admitted-but-incomplete requests.
    pub fn in_flight_peak(&self) -> usize {
        self.in_flight_peak
    }

    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    pub fn cache_misses(&self) -> u64 {
        self.cache_misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn cold_load_s(&self) -> f64 {
        self.cold_load_s
    }

    /// Warm-hit fraction of all placement-checked dispatches (0 when
    /// placement was off).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Fraction of offered requests rejected by admission control.
    pub fn drop_rate(&self) -> f64 {
        let offered = self.dropped + self.count() as u64;
        if offered == 0 {
            0.0
        } else {
            self.dropped as f64 / offered as f64
        }
    }

    pub fn count(&self) -> usize {
        self.latencies.len()
    }

    /// Mean time-in-system (submission -> result).
    pub fn mean_latency(&self) -> f64 {
        crate::util::stats::mean(&self.latencies)
    }

    pub fn median_latency(&self) -> f64 {
        self.latency_quantile(50.0)
    }

    pub fn p95_latency(&self) -> f64 {
        self.latency_quantile(95.0)
    }

    pub fn p99_latency(&self) -> f64 {
        self.latency_quantile(99.0)
    }

    pub fn mean_queue_wait(&self) -> f64 {
        self.queue_waits.mean()
    }

    pub fn mean_gen_time(&self) -> f64 {
        self.gen_times.mean()
    }

    /// Total makespan: first submission to last completion (the "total
    /// generation delay" of Table V).
    pub fn makespan(&self) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            self.last_complete - self.first_submit
        }
    }

    /// Images per second over the makespan.
    pub fn throughput(&self) -> f64 {
        let m = self.makespan();
        if m > 0.0 {
            self.count() as f64 / m
        } else {
            0.0
        }
    }

    /// Completion rate (img/s) per consecutive `window`-second window
    /// from first submission to last completion — the steady-state
    /// throughput trace of an open-loop run. The final window is
    /// normalized by its actual (possibly partial) width, so the trace
    /// doesn't end in a spurious cliff.
    pub fn windowed_throughput(&self, window: f64) -> Vec<f64> {
        if self.completions.is_empty() || window <= 0.0 {
            return Vec::new();
        }
        let t0 = self.first_submit;
        let span = (self.last_complete - t0).max(0.0);
        let n_win = ((span / window).ceil() as usize).max(1);
        let mut counts = vec![0u64; n_win];
        for &c in &self.completions {
            let i = (((c - t0) / window).floor() as usize).min(n_win - 1);
            counts[i] += 1;
        }
        counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let tail = span - i as f64 * window;
                let width = if tail > 0.0 { tail.min(window) } else { window };
                c as f64 / width
            })
            .collect()
    }

    /// Fraction of the makespan each worker spent occupied (generating,
    /// plus cold model loads when placement is on).
    pub fn utilization(&self) -> Vec<f64> {
        let m = self.makespan();
        if m <= 0.0 {
            return vec![0.0; self.busy.len()];
        }
        self.busy.iter().map(|&b| b / m).collect()
    }

    pub fn mean_utilization(&self) -> f64 {
        let u = self.utilization();
        // stats::mean is NaN on empty; a zero-worker fleet reads as 0
        if u.is_empty() {
            0.0
        } else {
            crate::util::stats::mean(&u)
        }
    }

    /// Load-balance factor: max/mean per-worker completions (1.0 =
    /// perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = *self.per_worker.iter().max().unwrap_or(&0) as f64;
        let mean =
            self.per_worker.iter().sum::<u64>() as f64 / self.per_worker.len() as f64;
        if mean > 0.0 {
            max / mean
        } else {
            0.0
        }
    }

    pub fn per_worker(&self) -> &[u64] {
        &self.per_worker
    }

    /// Record the engine's per-stream RNG draw ledger at drain time.
    pub fn set_rng_audit(&mut self, audit: RngAudit) {
        self.rng_audit = audit;
    }

    /// Per-stream RNG draw counts (empty when the engine did not
    /// record them, e.g. the real-time path).
    pub fn rng_audit(&self) -> &RngAudit {
        &self.rng_audit
    }

    /// Attach the sealed observability recording at drain time.
    pub fn set_trace(&mut self, trace: TraceLog) {
        self.trace = Some(trace);
    }

    /// The observability recording, when the run was traced.
    pub fn trace(&self) -> Option<&TraceLog> {
        self.trace.as_ref()
    }

    /// Attach the sealed decision recording at drain time.
    pub fn set_decisions(&mut self, book: DecisionBook) {
        self.decisions = Some(book);
    }

    /// The decision recording, when the run was decision-armed.
    pub fn decisions(&self) -> Option<&DecisionBook> {
        self.decisions.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, worker: usize, latency: f64) -> Response {
        Response {
            id,
            worker,
            z: 15,
            model: 0,
            latency,
            queue_wait: latency * 0.3,
            gen_time: latency * 0.7,
            trans_time: 0.0,
            checksum: 0.0,
            qos: 0,
            deadline: f64::INFINITY,
            demanded_z: 15,
            demanded_model: 0,
        }
    }

    #[test]
    fn aggregates_latency_and_makespan() {
        let mut m = ServeMetrics::new(2);
        m.record(&resp(0, 0, 10.0), 10.0); // submitted at 0
        m.record(&resp(1, 1, 10.0), 15.0); // submitted at 5
        assert_eq!(m.count(), 2);
        assert!((m.median_latency() - 10.0).abs() < 1e-9);
        assert!((m.mean_latency() - 10.0).abs() < 1e-9);
        assert!((m.makespan() - 15.0).abs() < 1e-9);
        assert!((m.throughput() - 2.0 / 15.0).abs() < 1e-9);
        assert_eq!(m.per_worker(), &[1, 1]);
        assert!((m.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn imbalance_detects_skew() {
        let mut m = ServeMetrics::new(2);
        for i in 0..4 {
            m.record(&resp(i, 0, 1.0), i as f64);
        }
        assert_eq!(m.imbalance(), 2.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_worker_is_a_hard_error() {
        // Regression: this used to be silently dropped, masking router
        // bugs behind a short `per_worker` histogram.
        let mut m = ServeMetrics::new(2);
        m.record(&resp(0, 2, 1.0), 1.0);
    }

    #[test]
    fn p99_orders_tail() {
        let mut m = ServeMetrics::new(1);
        for i in 0..100 {
            m.record(&resp(i, 0, (i + 1) as f64), (i + 1) as f64);
        }
        assert!(m.p99_latency() >= m.p95_latency());
        assert!(m.p95_latency() >= m.median_latency());
        assert!((m.p99_latency() - 99.01).abs() < 0.1);
    }

    #[test]
    fn quantile_cache_invalidates_on_new_records() {
        // Regression for the sort-once cache: reading a quantile, then
        // recording more data, then reading again must reflect the new
        // data (stale-cache bug), and repeated reads must agree.
        let mut m = ServeMetrics::new(1);
        for i in 0..10 {
            m.record(&resp(i, 0, (i + 1) as f64), (i + 1) as f64);
        }
        let before = m.median_latency();
        assert_eq!(before.to_bits(), m.median_latency().to_bits());
        m.record(&resp(10, 0, 1000.0), 1000.0);
        assert!(m.median_latency() > before);
        assert!(m.p99_latency() > 500.0);
    }

    #[test]
    fn queue_depth_high_water_marks() {
        let mut m = ServeMetrics::new(1);
        assert_eq!(m.queue_peak(), 0);
        assert_eq!(m.in_flight_peak(), 0);
        m.note_queue_depth(3, 2);
        m.note_queue_depth(7, 5);
        m.note_queue_depth(1, 1);
        assert_eq!(m.queue_peak(), 7);
        assert_eq!(m.in_flight_peak(), 5);
    }

    #[test]
    fn cache_and_drop_accounting() {
        let mut m = ServeMetrics::new(1);
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.drop_rate(), 0.0);
        m.record_cache(true, 0);
        m.record_cache(true, 0);
        m.record_cache(false, 2);
        m.record_cold_load_on(0, 8.5);
        assert_eq!(m.cache_hits(), 2);
        assert_eq!(m.cache_misses(), 1);
        assert_eq!(m.evictions(), 2);
        assert!((m.cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.cold_load_s() - 8.5).abs() < 1e-12);
        // 1 served + 3 dropped -> 75% drop rate
        m.record(&resp(0, 0, 1.0), 1.0);
        for _ in 0..3 {
            m.record_drop();
        }
        assert_eq!(m.dropped(), 3);
        assert!((m.drop_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn utilization_tracks_busy_time() {
        let mut m = ServeMetrics::new(2);
        // worker 0 generates for 7.0 s of a 10 s makespan, worker 1 idle
        m.record(
            &Response {
                id: 0,
                worker: 0,
                z: 15,
                model: 0,
                latency: 10.0,
                queue_wait: 3.0,
                gen_time: 7.0,
                trans_time: 0.0,
                checksum: 0.0,
                qos: 0,
                deadline: f64::INFINITY,
                demanded_z: 15,
                demanded_model: 0,
            },
            10.0,
        );
        let u = m.utilization();
        assert!((u[0] - 0.7).abs() < 1e-9, "u={u:?}");
        assert_eq!(u[1], 0.0);
        assert!((m.mean_utilization() - 0.35).abs() < 1e-9);
    }

    #[test]
    fn link_and_decomposition_accounting() {
        let mut m = ServeMetrics::new(1);
        assert!(m.link_stats().is_empty());
        assert_eq!(m.decomposition_error(), 0.0);
        m.record_transfer(0, 1, 1.0e6, 0.1);
        m.record_transfer(0, 1, 2.0e6, 0.2);
        m.record_transfer(1, 0, 0.5e6, 0.05);
        let st = m.link_stats()[&(0, 1)];
        assert_eq!(st.transfers, 2);
        assert!((st.bits - 3.0e6).abs() < 1e-6);
        assert!((st.secs - 0.3).abs() < 1e-12);
        assert_eq!(m.link_stats()[&(1, 0)].transfers, 1);
        // a response whose legs sum exactly leaves no residual...
        m.record(
            &Response {
                id: 0,
                worker: 0,
                z: 15,
                model: 0,
                latency: 10.0,
                queue_wait: 2.5,
                gen_time: 7.0,
                trans_time: 0.5,
                checksum: 0.0,
                qos: 0,
                deadline: f64::INFINITY,
                demanded_z: 15,
                demanded_model: 0,
            },
            10.0,
        );
        assert!(m.decomposition_error() < 1e-12);
        assert!((m.mean_trans_time() - 0.5).abs() < 1e-12);
        // ...and one that violates the identity is caught
        m.record(
            &Response {
                id: 1,
                worker: 0,
                z: 15,
                model: 0,
                latency: 10.0,
                queue_wait: 1.0,
                gen_time: 7.0,
                trans_time: 0.5,
                checksum: 0.0,
                qos: 0,
                deadline: f64::INFINITY,
                demanded_z: 15,
                demanded_model: 0,
            },
            20.0,
        );
        assert!(m.decomposition_error() > 0.1);
    }

    #[test]
    fn class_books_stay_empty_until_armed_then_ledger_degradations() {
        let mut m = ServeMetrics::new(1);
        assert!(!m.qos_active());
        // unarmed: even a classed response books nothing (the pre-QoS
        // structural parity guarantee)
        let classed = Response {
            qos: 1,
            deadline: 5.0,
            ..resp(0, 0, 10.0)
        };
        m.record(&classed, 10.0);
        assert!(m.class_stats().is_empty());
        assert_eq!(m.deadline_miss_rate(), 0.0);
        // armed: misses, degradations, and reroutes all book per class
        let mut m = ServeMetrics::new(1);
        m.set_qos_active();
        // premium completion at t=10 with deadline 5 -> miss
        m.record(&Response { qos: 1, deadline: 5.0, ..resp(0, 0, 10.0) }, 10.0);
        // premium completion within deadline, degraded z (8 < 15)
        m.record(
            &Response { qos: 1, deadline: 30.0, z: 8, ..resp(1, 0, 4.0) },
            4.0,
        );
        // standard completion rerouted to another model
        m.record(
            &Response { qos: 2, deadline: 60.0, model: 2, ..resp(2, 0, 6.0) },
            6.0,
        );
        let premium = &m.class_stats()[&1];
        assert_eq!(premium.count, 2);
        assert_eq!(premium.misses, 1);
        assert_eq!(premium.degraded, 1);
        assert_eq!(premium.rerouted, 0);
        assert!((premium.miss_rate() - 0.5).abs() < 1e-12);
        assert!(premium.p99() >= premium.p50());
        let standard = &m.class_stats()[&2];
        assert_eq!(standard.count, 1);
        assert_eq!(standard.misses, 0);
        assert_eq!(standard.rerouted, 1);
        assert!((m.deadline_miss_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.degradations(), (1, 1));
    }

    #[test]
    fn fault_ledger_stays_zero_until_armed_then_books_everything() {
        // unarmed: every fault hook is a no-op (the faults-off
        // structural-parity guarantee)
        let mut m = ServeMetrics::new(2);
        assert!(!m.faults_active());
        m.record_kill();
        m.record_retry();
        m.record_recovered();
        m.record_retry_exhausted();
        m.record_site_down();
        m.record_site_up(5.0);
        m.record_link_event();
        m.record_downtime(0, 3.0);
        assert_eq!(m.faults(), &FaultLedger::new(2));
        assert_eq!(m.availability(), vec![1.0, 1.0]);
        assert_eq!(m.drain_after_recovery_s(), 0.0);
        // armed: the ledger books each hook
        let mut m = ServeMetrics::new(2);
        m.set_faults_active();
        m.record_site_down();
        m.record_kill();
        m.record_kill();
        m.record_retry();
        m.record_retry_exhausted();
        m.record_site_up(6.0);
        m.record_recovered();
        m.record_link_event();
        m.record_downtime(1, 5.0);
        m.record_downtime(99, 1.0); // out of range: ignored, not a panic
        let f = m.faults();
        assert_eq!(
            (f.kills, f.retries, f.recovered, f.exhausted_retries),
            (2, 1, 1, 1)
        );
        assert_eq!(
            (f.site_down_events, f.site_up_events, f.link_events),
            (1, 1, 1)
        );
        assert_eq!(f.downtime_s, vec![0.0, 5.0]);
        assert_eq!(f.last_recovery_t, Some(6.0));
        // availability over a 10 s makespan: worker 1 was down half
        m.record(&resp(0, 0, 2.0), 2.0); // submitted at 0
        m.record(&resp(1, 0, 2.0), 10.0); // submitted at 8
        let a = m.availability();
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert!((a[1] - 0.5).abs() < 1e-12, "a={a:?}");
        assert!((m.mean_availability() - 0.75).abs() < 1e-12);
        // last completion at t=10, last recovery at t=6
        assert!((m.drain_after_recovery_s() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn windowed_throughput_counts_completions() {
        let mut m = ServeMetrics::new(1);
        // submissions at t=0 (latency == completion time)
        for (i, &t) in [1.0f64, 2.0, 3.0, 12.0].iter().enumerate() {
            m.record(&resp(i as u64, 0, t), t);
        }
        let w = m.windowed_throughput(10.0);
        assert_eq!(w.len(), 2);
        assert!((w[0] - 0.3).abs() < 1e-9); // 3 completions / 10 s
        // last window spans only [10, 12): 1 completion / 2 s
        assert!((w[1] - 0.5).abs() < 1e-9, "w={w:?}");
        assert!(m.windowed_throughput(0.0).is_empty());
        assert!(ServeMetrics::new(1).windowed_throughput(5.0).is_empty());
    }
}
