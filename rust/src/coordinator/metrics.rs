//! Serving metrics: latency distribution, throughput, per-worker load.

use crate::util::stats::{percentile, Welford};

use super::message::Response;

#[derive(Clone, Debug)]
pub struct ServeMetrics {
    latencies: Vec<f64>,
    queue_waits: Welford,
    gen_times: Welford,
    per_worker: Vec<u64>,
    first_submit: f64,
    last_complete: f64,
}

impl ServeMetrics {
    pub fn new(workers: usize) -> Self {
        Self {
            latencies: Vec::new(),
            queue_waits: Welford::new(),
            gen_times: Welford::new(),
            per_worker: vec![0; workers],
            first_submit: f64::INFINITY,
            last_complete: 0.0,
        }
    }

    pub fn record(&mut self, resp: &Response, completed_at: f64) {
        self.latencies.push(resp.latency);
        self.queue_waits.push(resp.queue_wait);
        self.gen_times.push(resp.gen_time);
        if resp.worker < self.per_worker.len() {
            self.per_worker[resp.worker] += 1;
        }
        self.first_submit = self
            .first_submit
            .min(completed_at - resp.latency);
        self.last_complete = self.last_complete.max(completed_at);
    }

    pub fn count(&self) -> usize {
        self.latencies.len()
    }

    pub fn median_latency(&self) -> f64 {
        percentile(&self.latencies, 50.0)
    }

    pub fn p95_latency(&self) -> f64 {
        percentile(&self.latencies, 95.0)
    }

    pub fn mean_queue_wait(&self) -> f64 {
        self.queue_waits.mean()
    }

    pub fn mean_gen_time(&self) -> f64 {
        self.gen_times.mean()
    }

    /// Total makespan: first submission to last completion (the "total
    /// generation delay" of Table V).
    pub fn makespan(&self) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            self.last_complete - self.first_submit
        }
    }

    /// Images per second over the makespan.
    pub fn throughput(&self) -> f64 {
        let m = self.makespan();
        if m > 0.0 {
            self.count() as f64 / m
        } else {
            0.0
        }
    }

    /// Load-balance factor: max/mean per-worker completions (1.0 =
    /// perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = *self.per_worker.iter().max().unwrap_or(&0) as f64;
        let mean =
            self.per_worker.iter().sum::<u64>() as f64 / self.per_worker.len() as f64;
        if mean > 0.0 {
            max / mean
        } else {
            0.0
        }
    }

    pub fn per_worker(&self) -> &[u64] {
        &self.per_worker
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, worker: usize, latency: f64) -> Response {
        Response {
            id,
            worker,
            latency,
            queue_wait: latency * 0.3,
            gen_time: latency * 0.7,
            checksum: 0.0,
        }
    }

    #[test]
    fn aggregates_latency_and_makespan() {
        let mut m = ServeMetrics::new(2);
        m.record(&resp(0, 0, 10.0), 10.0); // submitted at 0
        m.record(&resp(1, 1, 10.0), 15.0); // submitted at 5
        assert_eq!(m.count(), 2);
        assert!((m.median_latency() - 10.0).abs() < 1e-9);
        assert!((m.makespan() - 15.0).abs() < 1e-9);
        assert!((m.throughput() - 2.0 / 15.0).abs() < 1e-9);
        assert_eq!(m.per_worker(), &[1, 1]);
        assert!((m.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn imbalance_detects_skew() {
        let mut m = ServeMetrics::new(2);
        for i in 0..4 {
            m.record(&resp(i, 0, 1.0), i as f64);
        }
        assert_eq!(m.imbalance(), 2.0);
    }
}
