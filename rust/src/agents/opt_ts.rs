//! Opt-TS: the heuristic-optimal baseline (§V.B). Selects the ES
//! minimising the task's Eqn-2 service delay by enumerating the whole
//! action space with *live* knowledge of every ES's compute capacity,
//! link rates, and intra-slot queue build-up — information a real
//! distributed scheduler cannot have, which is why the paper treats it
//! as the performance upper bound.

use crate::env::{AigcTask, EdgeEnv};

use super::{Method, Scheduler};

#[derive(Default)]
pub struct OptTs;

impl OptTs {
    pub fn new() -> Self {
        OptTs
    }

    fn best_es(task: &AigcTask, env: &EdgeEnv) -> usize {
        let mut best = 0usize;
        let mut best_delay = f64::INFINITY;
        for es in 0..env.cfg.num_bs {
            let d = env.peek_delay(task, es).total();
            if d < best_delay {
                best_delay = d;
                best = es;
            }
        }
        best
    }
}

impl Scheduler for OptTs {
    fn method(&self) -> Method {
        Method::OptTs
    }

    fn sequential(&self) -> bool {
        true
    }

    fn decide_one(&mut self, task: &AigcTask, env: &EdgeEnv) -> usize {
        Self::best_es(task, env)
    }

    /// Batched fallback (used only if a caller ignores `sequential`).
    fn decide(&mut self, _b: usize, tasks: &[AigcTask], env: &EdgeEnv) -> Vec<usize> {
        tasks.iter().map(|t| Self::best_es(t, env)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;

    #[test]
    fn picks_min_peek_delay() {
        let mut cfg = EnvConfig::default();
        cfg.num_bs = 5;
        let env = EdgeEnv::new(&cfg, 7);
        let task = env.tasks()[0][0].clone();
        let mut opt = OptTs::new();
        let es = opt.decide_one(&task, &env);
        let d_best = env.peek_delay(&task, es).total();
        for other in 0..cfg.num_bs {
            assert!(d_best <= env.peek_delay(&task, other).total() + 1e-12);
        }
    }

    #[test]
    fn adapts_to_queue_buildup() {
        let mut cfg = EnvConfig::default();
        cfg.num_bs = 3;
        let mut env = EdgeEnv::new(&cfg, 8);
        let task = env.tasks()[0][0].clone();
        let mut opt = OptTs::new();
        let first = opt.decide_one(&task, &env);
        // pile work onto the chosen ES until it is no longer optimal
        for _ in 0..500 {
            env.assign(&task, first);
        }
        let second = opt.decide_one(&task, &env);
        assert_ne!(first, second, "oracle must react to live backlog");
    }
}
