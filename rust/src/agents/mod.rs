//! Scheduling agents: the paper's LAD-TS plus every baseline of §V.B
//! (DQN-TS, SAC-TS, D2SAC-TS, Opt-TS) and additional sanity heuristics.
//!
//! Protocol per slot t (driven by `sim::runner`):
//! 1. `decide(b, tasks, env)` — batched decisions for BS b's arrivals
//!    (state = Eqn 6 with q_{t-1}, so batching is exact);
//! 2. assignments execute in arrival order; the runner reports realized
//!    rewards via `rewards(b, ...)`;
//! 3. `train_tick(b)` — the periodic offline training of Algorithm 1
//!    (runs the AOT HLO train-step graphs through PJRT);
//! 4. sequential agents (Opt-TS, least-loaded) instead opt into
//!    `decide_one` at assignment time with live queue knowledge.

pub mod dqn_ts;
pub mod drl_common;
pub mod heuristics;
pub mod lad_ts;
pub mod latent;
pub mod opt_ts;
pub mod replay;
pub mod sac_ts;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::AgentConfig;
use crate::env::{AigcTask, EdgeEnv};
use crate::runtime::{Metrics, XlaRuntime};
use crate::util::rng::Rng;

/// All scheduling methods of the evaluation section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// The paper's contribution (latent action diffusion SAC).
    LadTs,
    /// Diffusion SAC from Gaussian noise (Du et al.).
    D2SacTs,
    /// Discrete soft actor-critic.
    SacTs,
    /// Deep Q-network with epsilon-greedy.
    DqnTs,
    /// Greedy oracle enumerating all ESs with live queue knowledge.
    OptTs,
    Random,
    RoundRobin,
    /// Always process at the originating ES.
    Local,
    /// Send to the ES with the least pending work (in seconds).
    LeastLoaded,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "lad" | "lad-ts" | "ladts" => Method::LadTs,
            "d2sac" | "d2sac-ts" => Method::D2SacTs,
            "sac" | "sac-ts" => Method::SacTs,
            "dqn" | "dqn-ts" => Method::DqnTs,
            "opt" | "opt-ts" | "oracle" => Method::OptTs,
            "random" => Method::Random,
            "rr" | "round-robin" | "roundrobin" => Method::RoundRobin,
            "local" => Method::Local,
            "least-loaded" | "leastloaded" | "ll" => Method::LeastLoaded,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::LadTs => "LAD-TS",
            Method::D2SacTs => "D2SAC-TS",
            Method::SacTs => "SAC-TS",
            Method::DqnTs => "DQN-TS",
            Method::OptTs => "Opt-TS",
            Method::Random => "Random",
            Method::RoundRobin => "RoundRobin",
            Method::Local => "Local",
            Method::LeastLoaded => "LeastLoaded",
        }
    }

    /// The four learning methods compared in Figs 5-7.
    pub fn learners() -> [Method; 4] {
        [Method::DqnTs, Method::SacTs, Method::D2SacTs, Method::LadTs]
    }

    /// Everything plotted in Fig 5 (learners + oracle).
    pub fn fig5_set() -> [Method; 5] {
        [
            Method::DqnTs,
            Method::SacTs,
            Method::D2SacTs,
            Method::LadTs,
            Method::OptTs,
        ]
    }

    pub fn is_learner(&self) -> bool {
        matches!(
            self,
            Method::LadTs | Method::D2SacTs | Method::SacTs | Method::DqnTs
        )
    }
}

/// One stored experience tuple. For the diffusion agents the tuple is
/// the paper's extended form (s, x_I, a, r, s', x'_I); `x`/`x2` are
/// empty for SAC/DQN.
#[derive(Clone, Debug)]
pub struct Transition {
    pub s: Vec<f32>,
    pub x: Vec<f32>,
    pub a: usize,
    pub r: f32,
    pub s2: Vec<f32>,
    pub x2: Vec<f32>,
}

/// Outcome of one [`Scheduler::train_tick`]: how many gradient steps
/// actually executed this tick (up to `Cadence::max_steps_per_tick`)
/// and the metrics of the last one.
#[derive(Clone, Copy, Debug, Default)]
pub struct TickOutcome {
    pub steps: usize,
    pub metrics: Option<Metrics>,
}

/// A task scheduler (one per method; internally per-BS agents).
///
/// `Send` is a supertrait so schedulers can be constructed inside the
/// `sim::parallel` worker threads (and moved across threads if a
/// future harness wants to); every constituent (train states, replay
/// buffers, RNGs, `Arc<XlaRuntime>`) is plain data or thread-safe.
pub trait Scheduler: Send {
    fn method(&self) -> Method;

    /// Batched decision for BS `b`'s slot arrivals. Returns one ES
    /// index per task.
    fn decide(&mut self, b: usize, tasks: &[AigcTask], env: &EdgeEnv) -> Vec<usize>;

    /// True if the agent decides per task at assignment time with live
    /// queue state (Opt-TS, LeastLoaded).
    fn sequential(&self) -> bool {
        false
    }

    /// Sequential decision (only called when `sequential()`).
    fn decide_one(&mut self, _task: &AigcTask, _env: &EdgeEnv) -> usize {
        unreachable!("not a sequential scheduler")
    }

    /// Realized rewards (Eqn 9, unscaled: -T_serv) for the tasks of the
    /// latest `decide(b, ...)`, in the same order.
    fn rewards(&mut self, _b: usize, _rewards: &[f64]) {}

    /// Periodic offline training (Algorithm 1 lines 15-18); called once
    /// per (BS, slot). Reports the number of gradient steps that ran
    /// (possibly several per tick) and the last step's metrics.
    fn train_tick(&mut self, _b: usize) -> Result<TickOutcome> {
        Ok(TickOutcome::default())
    }

    /// Episode boundary (env reset follows).
    fn end_episode(&mut self) {}
}

/// Instantiate a scheduler. Learning methods require the AOT runtime;
/// heuristics and the oracle do not.
pub fn make_scheduler(
    method: Method,
    num_bs: usize,
    cfg: &AgentConfig,
    runtime: Option<Arc<XlaRuntime>>,
    seed: u64,
) -> Result<Box<dyn Scheduler>> {
    let rng = Rng::new(seed);
    Ok(match method {
        Method::LadTs => Box::new(lad_ts::LadTsAgent::new(
            runtime_required(runtime, method)?,
            num_bs,
            cfg,
            rng,
            /*latent_memory=*/ true,
        )?),
        Method::D2SacTs => Box::new(lad_ts::LadTsAgent::new(
            runtime_required(runtime, method)?,
            num_bs,
            cfg,
            rng,
            /*latent_memory=*/ false,
        )?),
        Method::SacTs => Box::new(sac_ts::SacTsAgent::new(
            runtime_required(runtime, method)?,
            num_bs,
            cfg,
            rng,
        )?),
        Method::DqnTs => Box::new(dqn_ts::DqnTsAgent::new(
            runtime_required(runtime, method)?,
            num_bs,
            cfg,
            rng,
        )?),
        Method::OptTs => Box::new(opt_ts::OptTs::new()),
        Method::Random => Box::new(heuristics::RandomTs::new(num_bs, rng)),
        Method::RoundRobin => Box::new(heuristics::RoundRobinTs::new(num_bs)),
        Method::Local => Box::new(heuristics::LocalTs::new()),
        Method::LeastLoaded => Box::new(heuristics::LeastLoadedTs::new()),
    })
}

fn runtime_required(
    runtime: Option<Arc<XlaRuntime>>,
    method: Method,
) -> Result<Arc<XlaRuntime>> {
    match runtime {
        Some(rt) => Ok(rt),
        None => bail!(
            "{} needs the AOT artifacts (run `make artifacts`)",
            method.name()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parsing_aliases() {
        assert_eq!(Method::parse("lad-ts").unwrap(), Method::LadTs);
        assert_eq!(Method::parse("LAD_TS").unwrap(), Method::LadTs);
        assert_eq!(Method::parse("d2sac").unwrap(), Method::D2SacTs);
        assert_eq!(Method::parse("oracle").unwrap(), Method::OptTs);
        assert_eq!(Method::parse("ll").unwrap(), Method::LeastLoaded);
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn learner_partition() {
        assert!(Method::LadTs.is_learner());
        assert!(!Method::OptTs.is_learner());
        assert_eq!(Method::learners().len(), 4);
        assert!(Method::fig5_set().contains(&Method::OptTs));
    }

    #[test]
    fn scheduler_trait_objects_are_send() {
        fn assert_send<T: Send + ?Sized>() {}
        assert_send::<dyn Scheduler>();
        assert_send::<Box<dyn Scheduler>>();
    }

    #[test]
    fn learners_without_runtime_fail_cleanly() {
        let cfg = AgentConfig::default();
        let err = make_scheduler(Method::LadTs, 4, &cfg, None, 1);
        assert!(err.is_err());
        assert!(make_scheduler(Method::OptTs, 4, &cfg, None, 1).is_ok());
    }
}
