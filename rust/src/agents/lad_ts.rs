//! LAD-TS — the paper's method — and D2SAC-TS, its Gaussian-noise
//! ablation (Du et al.), which shares the same LADN graphs.
//!
//! The agent is a per-BS soft actor-critic whose actor reverse-diffuses
//! an action-probability vector (Theorem 2). LAD-TS seeds the diffusion
//! from the stored latent X_b[n] and feeds the extended transition
//! (s, x_I, a, r, s', x'_I); D2SAC-TS seeds from fresh N(0, I) each
//! decision — that *is* the algorithmic difference the paper evaluates.
//!
//! Inference runs either natively (`nn::diffusion`, bit-compatible) or
//! through the AOT `ladn_actor_fwd_*` graph (the deployed path);
//! training always runs the `ladn_train_*` HLO via PJRT.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::config::{ActorLoss, AgentConfig, Backend};
use crate::env::{AigcTask, EdgeEnv};
use crate::nn::diffusion::{actor_forward, ActorScratch, BetaSchedule};
use crate::nn::{Mat, Mlp};
use crate::runtime::exec::BatchTensor;
use crate::runtime::{ActorFwdExec, Manifest, Metrics, TrainExec, TrainState, XlaRuntime};
use crate::util::rng::Rng;

use super::drl_common::{Cadence, Rec, TransitionLinker};
use super::latent::LatentMemory;
use super::replay::ReplayBuffer;
use super::{Method, Scheduler, TickOutcome};

pub struct LadTsAgent {
    rt: Arc<XlaRuntime>,
    cfg: AgentConfig,
    b_dim: usize,
    s_dim: usize,
    latent_memory: bool,
    /// Per-BS train states (single entry when share_params).
    states: Vec<TrainState>,
    /// Native actor mirrors, rebuilt after training.
    mirrors: Vec<Mlp>,
    sched: BetaSchedule,
    temb_dim: usize,
    fwd: Option<ActorFwdExec>,
    train: TrainExec,
    mem: LatentMemory,
    replay: Vec<ReplayBuffer>,
    linker: TransitionLinker,
    cadence: Cadence,
    rng: Rng,
    scratch: ActorScratch,
    last_metrics: Option<Metrics>,
}

impl LadTsAgent {
    pub fn new(
        rt: Arc<XlaRuntime>,
        num_bs: usize,
        cfg: &AgentConfig,
        mut rng: Rng,
        latent_memory: bool,
    ) -> Result<Self> {
        let b_dim = num_bs;
        let s_dim = b_dim + 2;
        ensure!(
            cfg.hidden == rt.manifest.hidden,
            "hidden={} but artifacts built with {}",
            cfg.hidden,
            rt.manifest.hidden
        );
        ensure!(
            cfg.batch_k == rt.manifest.train_k,
            "batch_k={} but artifacts built with {}",
            cfg.batch_k,
            rt.manifest.train_k
        );
        let train_name = Manifest::ladn_train(
            b_dim,
            cfg.denoise_steps,
            cfg.alpha_autotune,
            cfg.actor_loss == ActorLoss::Paper,
        );
        let train = TrainExec::new(&rt, &train_name).with_context(|| {
            format!(
                "LADN train graph '{train_name}' not in artifacts \
                 (B={b_dim}, I={}; rebuild with aot.py)",
                cfg.denoise_steps
            )
        })?;
        let fwd_name = Manifest::ladn_fwd(b_dim, cfg.denoise_steps);
        let fwd = match cfg.backend {
            Backend::Xla => Some(ActorFwdExec::new(&rt, &fwd_name)?),
            Backend::Native => None,
        };

        let n_states = if cfg.share_params { 1 } else { num_bs };
        let mut states = Vec::with_capacity(n_states);
        let mut mirrors = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            let st = TrainState::init(&train.spec, cfg.alpha0, &mut rng)?;
            let mirror = Mlp::from_flat(
                b_dim + rt.manifest.temb_dim + s_dim,
                cfg.hidden,
                b_dim,
                &st.mlp_tensors("actor")?,
            )?;
            states.push(st);
            mirrors.push(mirror);
        }
        let sched = BetaSchedule::new(
            cfg.denoise_steps,
            rt.manifest.beta_min,
            rt.manifest.beta_max,
        );
        let temb_dim = rt.manifest.temb_dim;
        Ok(Self {
            rt,
            cfg: cfg.clone(),

            b_dim,
            s_dim,
            latent_memory,
            states,
            mirrors,
            sched,
            temb_dim,
            fwd,
            train,
            mem: LatentMemory::new(num_bs, b_dim),
            replay: (0..num_bs).map(|_| ReplayBuffer::new(cfg.pool_size)).collect(),
            linker: TransitionLinker::new(num_bs),
            cadence: Cadence::new(num_bs, cfg.train_every),
            rng,
            scratch: ActorScratch::default(),
            last_metrics: None,
        })
    }

    fn state_idx(&self, b: usize) -> usize {
        if self.cfg.share_params {
            0
        } else {
            b
        }
    }

    /// Draw the diffusion start: stored latent (LAD) or N(0,I) (D2SAC).
    fn draw_x(&mut self, b: usize, n: usize) -> Vec<f32> {
        if self.latent_memory {
            self.mem.get(b, n, &mut self.rng).to_vec()
        } else {
            let mut v = vec![0.0f32; self.b_dim];
            self.rng.fill_normal(&mut v);
            v
        }
    }

    /// Batched actor forward, native or XLA. Returns (x0, pi).
    fn forward(&mut self, b: usize, x: Mat, s: &Mat) -> Result<(Mat, Mat)> {
        let idx = self.state_idx(b);
        match &self.fwd {
            Some(exec) => {
                let params = self.states[idx].mlp_tensors("actor")?;
                exec.run(&params, Some(&x), s, Some(&mut self.rng))
            }
            None => {
                let n = x.rows;
                let mut x = x;
                let noise: Vec<Mat> = (0..self.sched.steps())
                    .map(|_| {
                        let mut m = Mat::zeros(n, self.b_dim);
                        self.rng.fill_normal(&mut m.data);
                        m
                    })
                    .collect();
                let pi = actor_forward(
                    &self.mirrors[idx],
                    &self.sched,
                    self.temb_dim,
                    &mut x,
                    s,
                    Some(&noise),
                    &mut self.scratch,
                );
                Ok((x, pi))
            }
        }
    }

    fn rebuild_mirror(&mut self, idx: usize) -> Result<()> {
        self.mirrors[idx] = Mlp::from_flat(
            self.b_dim + self.temb_dim + self.s_dim,
            self.cfg.hidden,
            self.b_dim,
            &self.states[idx].mlp_tensors("actor")?,
        )?;
        Ok(())
    }

    fn train_batch(&mut self, b: usize) -> Result<Metrics> {
        let idx = self.state_idx(b);
        let k = self.cfg.batch_k;
        let i_steps = self.sched.steps();
        let (s_dim, b_dim) = (self.s_dim, self.b_dim);
        let samples = self.replay[b].sample(k, &mut self.rng);
        let mut s = Vec::with_capacity(k * s_dim);
        let mut x = Vec::with_capacity(k * b_dim);
        let mut a = Vec::with_capacity(k);
        let mut r = Vec::with_capacity(k);
        let mut s2 = Vec::with_capacity(k * s_dim);
        let mut x2 = Vec::with_capacity(k * b_dim);
        for t in &samples {
            s.extend_from_slice(&t.s);
            x.extend_from_slice(&t.x);
            a.push(t.a as i32);
            r.push(t.r);
            s2.extend_from_slice(&t.s2);
            x2.extend_from_slice(&t.x2);
        }
        drop(samples);
        let mut noise = vec![0.0f32; i_steps * k * b_dim];
        let mut noise2 = vec![0.0f32; i_steps * k * b_dim];
        self.rng.fill_normal(&mut noise);
        self.rng.fill_normal(&mut noise2);
        let batch = [
            BatchTensor::F32(vec![k, s_dim], s),
            BatchTensor::F32(vec![k, b_dim], x),
            BatchTensor::I32(vec![k], a),
            BatchTensor::F32(vec![k], r),
            BatchTensor::F32(vec![k, s_dim], s2),
            BatchTensor::F32(vec![k, b_dim], x2),
            BatchTensor::F32(vec![i_steps, k, b_dim], noise),
            BatchTensor::F32(vec![i_steps, k, b_dim], noise2),
        ];
        self.train.run(&mut self.states[idx], &batch)
    }
}

impl Scheduler for LadTsAgent {
    fn method(&self) -> Method {
        if self.latent_memory {
            Method::LadTs
        } else {
            Method::D2SacTs
        }
    }

    fn decide(&mut self, b: usize, tasks: &[AigcTask], env: &EdgeEnv) -> Vec<usize> {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let mut s = Mat::zeros(n, self.s_dim);
        let mut buf = Vec::with_capacity(self.s_dim);
        for (i, task) in tasks.iter().enumerate() {
            env.state_for(task, &mut buf);
            s.row_mut(i).copy_from_slice(&buf);
        }
        let mut x = Mat::zeros(n, self.b_dim);
        for i in 0..n {
            let xi = self.draw_x(b, tasks[i].slot_index);
            x.row_mut(i).copy_from_slice(&xi);
        }
        let x_start = x.clone();
        let mut actions = Vec::with_capacity(n);
        let mut recs = Vec::with_capacity(n);
        match self.forward(b, x, &s) {
            Ok((x0, pi)) => {
                for i in 0..n {
                    let action = self.rng.categorical(pi.row(i));
                    actions.push(action);
                    if self.latent_memory {
                        self.mem.update(b, tasks[i].slot_index, x0.row(i));
                    }
                    recs.push(Rec {
                        s: s.row(i).to_vec(),
                        x: x_start.row(i).to_vec(),
                        a: action,
                        r: None,
                    });
                }
            }
            Err(e) => {
                // Fall back to local processing — but still record the
                // decisions: the runner reports one reward per task, and
                // an empty slot in the linker would trip its arity check
                // on the next `rewards(b, ...)`. The executed fallback
                // actions are legitimate experience, so learn from them.
                log::error!("actor forward failed (local fallback): {e:#}");
                for (i, task) in tasks.iter().enumerate() {
                    actions.push(task.origin);
                    recs.push(Rec {
                        s: s.row(i).to_vec(),
                        x: x_start.row(i).to_vec(),
                        a: task.origin,
                        r: None,
                    });
                }
            }
        }
        if let Some(cross) = self.linker.begin(b, recs) {
            self.replay[b].push(cross);
        }
        self.cadence.add(b, n);
        actions
    }

    fn rewards(&mut self, b: usize, rewards: &[f64]) {
        let scaled: Vec<f32> = rewards
            .iter()
            .map(|&r| (r * self.cfg.reward_scale) as f32)
            .collect();
        for t in self.linker.rewards(b, &scaled) {
            self.replay[b].push(t);
        }
    }

    fn train_tick(&mut self, b: usize) -> Result<TickOutcome> {
        let steps = self.cadence.take(b);
        if steps == 0 || self.replay[b].len() < self.cfg.warmup.max(self.cfg.batch_k)
        {
            return Ok(TickOutcome::default());
        }
        let mut last = None;
        for _ in 0..steps {
            last = Some(self.train_batch(b)?);
        }
        self.rebuild_mirror(self.state_idx(b))?;
        self.last_metrics = last;
        Ok(TickOutcome { steps, metrics: last })
    }

    fn end_episode(&mut self) {
        // X_b persists across episodes (Algorithm 1 initialises it once,
        // line 1); only dangling transition chains are dropped.
        self.linker.reset();
    }
}

impl LadTsAgent {
    /// Current entropy temperature (diagnostics).
    pub fn alpha(&self, b: usize) -> f32 {
        self.states[self.state_idx(b)]
            .scalar("log_alpha")
            .map(|v| v.exp())
            .unwrap_or(f32::NAN)
    }

    pub fn last_metrics(&self) -> Option<Metrics> {
        self.last_metrics
    }

    /// Replay-pool fill level (diagnostics / tests).
    pub fn pool_len(&self, b: usize) -> usize {
        self.replay[b].len()
    }

    /// Expose the runtime for tests.
    pub fn runtime(&self) -> &XlaRuntime {
        &self.rt
    }
}
