//! SAC-TS baseline: discrete soft actor-critic with a categorical MLP
//! actor (Haarnoja et al., as instantiated in the paper's §V.B).

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::config::{AgentConfig, Backend};
use crate::env::{AigcTask, EdgeEnv};
use crate::nn::{Mat, Mlp, MlpScratch};
use crate::runtime::exec::BatchTensor;
use crate::runtime::{ActorFwdExec, Manifest, TrainExec, TrainState, XlaRuntime};
use crate::util::rng::Rng;

use super::drl_common::{Cadence, Rec, TransitionLinker};
use super::replay::ReplayBuffer;
use super::{Method, Scheduler, TickOutcome};

pub struct SacTsAgent {
    cfg: AgentConfig,
    b_dim: usize,
    s_dim: usize,
    states: Vec<TrainState>,
    mirrors: Vec<Mlp>,
    fwd: Option<ActorFwdExec>,
    train: TrainExec,
    replay: Vec<ReplayBuffer>,
    linker: TransitionLinker,
    cadence: Cadence,
    rng: Rng,
    scratch: MlpScratch,
}

impl SacTsAgent {
    pub fn new(
        rt: Arc<XlaRuntime>,
        num_bs: usize,
        cfg: &AgentConfig,
        mut rng: Rng,
    ) -> Result<Self> {
        let b_dim = num_bs;
        let s_dim = b_dim + 2;
        ensure!(cfg.hidden == rt.manifest.hidden, "hidden mismatch");
        let train = TrainExec::new(&rt, &Manifest::sac_train(b_dim))
            .with_context(|| format!("SAC train graph for B={b_dim}"))?;
        let fwd = match cfg.backend {
            Backend::Xla => Some(ActorFwdExec::new(&rt, &Manifest::sac_fwd(b_dim))?),
            Backend::Native => None,
        };
        let n_states = if cfg.share_params { 1 } else { num_bs };
        let mut states = Vec::with_capacity(n_states);
        let mut mirrors = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            let st = TrainState::init(&train.spec, cfg.alpha0, &mut rng)?;
            mirrors.push(Mlp::from_flat(
                s_dim,
                cfg.hidden,
                b_dim,
                &st.mlp_tensors("actor")?,
            )?);
            states.push(st);
        }
        Ok(Self {
            cfg: cfg.clone(),
            b_dim,
            s_dim,
            states,
            mirrors,
            fwd,
            train,
            replay: (0..num_bs)
                .map(|_| ReplayBuffer::new(cfg.pool_size))
                .collect(),
            linker: TransitionLinker::new(num_bs),
            cadence: Cadence::new(num_bs, cfg.train_every),
            rng,
            scratch: MlpScratch::default(),
        })
    }

    fn state_idx(&self, b: usize) -> usize {
        if self.cfg.share_params {
            0
        } else {
            b
        }
    }

    fn policy(&mut self, b: usize, s: &Mat) -> Result<Mat> {
        let idx = self.state_idx(b);
        match &self.fwd {
            Some(exec) => {
                let params = self.states[idx].mlp_tensors("actor")?;
                let (_logits, pi) = exec.run(&params, None, s, None)?;
                Ok(pi)
            }
            None => {
                let mut logits = Mat::default();
                self.mirrors[idx].forward_into(s, &mut self.scratch, &mut logits);
                logits.softmax_rows_inplace();
                Ok(logits)
            }
        }
    }
}

impl Scheduler for SacTsAgent {
    fn method(&self) -> Method {
        Method::SacTs
    }

    fn decide(&mut self, b: usize, tasks: &[AigcTask], env: &EdgeEnv) -> Vec<usize> {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let mut s = Mat::zeros(n, self.s_dim);
        let mut buf = Vec::with_capacity(self.s_dim);
        for (i, task) in tasks.iter().enumerate() {
            env.state_for(task, &mut buf);
            s.row_mut(i).copy_from_slice(&buf);
        }
        let mut actions = Vec::with_capacity(n);
        let mut recs = Vec::with_capacity(n);
        match self.policy(b, &s) {
            Ok(pi) => {
                for i in 0..n {
                    let action = self.rng.categorical(pi.row(i));
                    actions.push(action);
                    recs.push(Rec {
                        s: s.row(i).to_vec(),
                        x: Vec::new(),
                        a: action,
                        r: None,
                    });
                }
            }
            Err(e) => {
                // Record the fallback decisions so the linker's reward
                // arity stays consistent (see LadTsAgent::decide).
                log::error!("SAC policy failed (local fallback): {e:#}");
                for (i, task) in tasks.iter().enumerate() {
                    actions.push(task.origin);
                    recs.push(Rec {
                        s: s.row(i).to_vec(),
                        x: Vec::new(),
                        a: task.origin,
                        r: None,
                    });
                }
            }
        }
        if let Some(cross) = self.linker.begin(b, recs) {
            self.replay[b].push(cross);
        }
        self.cadence.add(b, n);
        actions
    }

    fn rewards(&mut self, b: usize, rewards: &[f64]) {
        let scaled: Vec<f32> = rewards
            .iter()
            .map(|&r| (r * self.cfg.reward_scale) as f32)
            .collect();
        for t in self.linker.rewards(b, &scaled) {
            self.replay[b].push(t);
        }
    }

    fn train_tick(&mut self, b: usize) -> Result<TickOutcome> {
        let steps = self.cadence.take(b);
        if steps == 0
            || self.replay[b].len() < self.cfg.warmup.max(self.cfg.batch_k)
        {
            return Ok(TickOutcome::default());
        }
        let idx = self.state_idx(b);
        let k = self.cfg.batch_k;
        let mut last = None;
        for _ in 0..steps {
            let samples = self.replay[b].sample(k, &mut self.rng);
            let mut s = Vec::with_capacity(k * self.s_dim);
            let mut a = Vec::with_capacity(k);
            let mut r = Vec::with_capacity(k);
            let mut s2 = Vec::with_capacity(k * self.s_dim);
            for t in &samples {
                s.extend_from_slice(&t.s);
                a.push(t.a as i32);
                r.push(t.r);
                s2.extend_from_slice(&t.s2);
            }
            drop(samples);
            let batch = [
                BatchTensor::F32(vec![k, self.s_dim], s),
                BatchTensor::I32(vec![k], a),
                BatchTensor::F32(vec![k], r),
                BatchTensor::F32(vec![k, self.s_dim], s2),
            ];
            last = Some(self.train.run(&mut self.states[idx], &batch)?);
        }
        self.mirrors[idx] = Mlp::from_flat(
            self.s_dim,
            self.cfg.hidden,
            self.b_dim,
            &self.states[idx].mlp_tensors("actor")?,
        )?;
        Ok(TickOutcome { steps, metrics: last })
    }

    fn end_episode(&mut self) {
        self.linker.reset();
    }
}
