//! Non-learning sanity baselines beyond the paper's set: Random,
//! RoundRobin, Local (no offloading), and LeastLoaded (live
//! backlog-seconds greedy). Used by the ablation bench and tests to
//! bracket the learning methods.

use crate::env::{AigcTask, EdgeEnv};
use crate::util::rng::Rng;

use super::{Method, Scheduler};

/// Uniform-random ES choice.
pub struct RandomTs {
    num_bs: usize,
    rng: Rng,
}

impl RandomTs {
    pub fn new(num_bs: usize, rng: Rng) -> Self {
        Self { num_bs, rng }
    }
}

impl Scheduler for RandomTs {
    fn method(&self) -> Method {
        Method::Random
    }

    fn decide(&mut self, _b: usize, tasks: &[AigcTask], _env: &EdgeEnv) -> Vec<usize> {
        tasks
            .iter()
            .map(|_| self.rng.range_usize(0, self.num_bs - 1))
            .collect()
    }
}

/// Global round-robin across ESs.
pub struct RoundRobinTs {
    num_bs: usize,
    next: usize,
}

impl RoundRobinTs {
    pub fn new(num_bs: usize) -> Self {
        Self { num_bs, next: 0 }
    }
}

impl Scheduler for RoundRobinTs {
    fn method(&self) -> Method {
        Method::RoundRobin
    }

    fn decide(&mut self, _b: usize, tasks: &[AigcTask], _env: &EdgeEnv) -> Vec<usize> {
        tasks
            .iter()
            .map(|_| {
                let es = self.next;
                self.next = (self.next + 1) % self.num_bs;
                es
            })
            .collect()
    }
}

/// Everything processed at the originating ES (the no-collaboration
/// baseline — what a cloudless, non-cooperative edge would do).
#[derive(Default)]
pub struct LocalTs;

impl LocalTs {
    pub fn new() -> Self {
        LocalTs
    }
}

impl Scheduler for LocalTs {
    fn method(&self) -> Method {
        Method::Local
    }

    fn decide(&mut self, b: usize, tasks: &[AigcTask], _env: &EdgeEnv) -> Vec<usize> {
        tasks.iter().map(|_| b).collect()
    }
}

/// Greedy least-loaded: the ES with the fewest pending backlog-seconds
/// (live intra-slot view, like Opt-TS but ignoring transmission and
/// compute heterogeneity of the task itself).
#[derive(Default)]
pub struct LeastLoadedTs;

impl LeastLoadedTs {
    pub fn new() -> Self {
        LeastLoadedTs
    }
}

impl Scheduler for LeastLoadedTs {
    fn method(&self) -> Method {
        Method::LeastLoaded
    }

    fn sequential(&self) -> bool {
        true
    }

    fn decide_one(&mut self, _task: &AigcTask, env: &EdgeEnv) -> usize {
        let mut best = 0;
        let mut best_load = f64::INFINITY;
        for es in 0..env.cfg.num_bs {
            let load = env.pending(es) / env.topo.f[es];
            if load < best_load {
                best_load = load;
                best = es;
            }
        }
        best
    }

    fn decide(&mut self, _b: usize, tasks: &[AigcTask], env: &EdgeEnv) -> Vec<usize> {
        tasks.iter().map(|t| self.decide_one(t, env)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;

    fn env4() -> EdgeEnv {
        let mut cfg = EnvConfig::default();
        cfg.num_bs = 4;
        EdgeEnv::new(&cfg, 1)
    }

    #[test]
    fn random_in_range() {
        let env = env4();
        let tasks = env.tasks()[0].clone();
        let mut r = RandomTs::new(4, Rng::new(1));
        for es in r.decide(0, &tasks, &env) {
            assert!(es < 4);
        }
    }

    #[test]
    fn round_robin_cycles() {
        let env = env4();
        let tasks: Vec<_> = env.tasks().iter().flatten().cloned().collect();
        let mut rr = RoundRobinTs::new(4);
        let picks = rr.decide(0, &tasks[..4.min(tasks.len())], &env);
        for (i, es) in picks.iter().enumerate() {
            assert_eq!(*es, i % 4);
        }
    }

    #[test]
    fn local_stays_home() {
        let env = env4();
        let tasks = env.tasks()[2].clone();
        let mut l = LocalTs::new();
        assert!(l.decide(2, &tasks, &env).iter().all(|&es| es == 2));
    }

    #[test]
    fn least_loaded_avoids_busy_es() {
        let mut env = env4();
        let task = env.tasks()[0][0].clone();
        let mut ll = LeastLoadedTs::new();
        let first = ll.decide_one(&task, &env);
        for _ in 0..500 {
            env.assign(&task, first);
        }
        assert_ne!(ll.decide_one(&task, &env), first);
    }
}
