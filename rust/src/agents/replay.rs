//! Experience pool R_b (Algorithm 1): a ring buffer of transitions with
//! uniform sampling.

use crate::util::rng::Rng;

use super::Transition;

/// Fixed-capacity ring buffer.
#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    capacity: usize,
    items: Vec<Transition>,
    next: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { capacity, items: Vec::with_capacity(capacity), next: 0 }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn push(&mut self, t: Transition) {
        if self.items.len() < self.capacity {
            self.items.push(t);
        } else {
            self.items[self.next] = t;
        }
        self.next = (self.next + 1) % self.capacity;
    }

    /// Sample `k` transitions uniformly with replacement-free indices
    /// when k <= len, otherwise with replacement (warm-up edge case).
    pub fn sample<'a>(&'a self, k: usize, rng: &mut Rng) -> Vec<&'a Transition> {
        if self.items.is_empty() {
            return Vec::new();
        }
        if k <= self.items.len() {
            rng.sample_indices(self.items.len(), k)
                .into_iter()
                .map(|i| &self.items[i])
                .collect()
        } else {
            (0..k)
                .map(|_| &self.items[rng.range_usize(0, self.items.len() - 1)])
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f32) -> Transition {
        Transition {
            s: vec![r],
            x: vec![],
            a: 0,
            r,
            s2: vec![r],
            x2: vec![],
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), 3);
        let rewards: Vec<f32> = rb.items.iter().map(|x| x.r).collect();
        // 0 and 1 evicted; 3,4 wrapped over them
        assert!(rewards.contains(&2.0));
        assert!(rewards.contains(&3.0));
        assert!(rewards.contains(&4.0));
    }

    #[test]
    fn sample_distinct_when_possible() {
        let mut rb = ReplayBuffer::new(100);
        for i in 0..50 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(1);
        let s = rb.sample(20, &mut rng);
        assert_eq!(s.len(), 20);
        let mut rs: Vec<f32> = s.iter().map(|x| x.r).collect();
        rs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rs.dedup();
        assert_eq!(rs.len(), 20, "sampling without replacement");
    }

    #[test]
    fn sample_small_pool_with_replacement() {
        let mut rb = ReplayBuffer::new(10);
        rb.push(t(1.0));
        let mut rng = Rng::new(2);
        assert_eq!(rb.sample(4, &mut rng).len(), 4);
        assert!(ReplayBuffer::new(5).sample(3, &mut rng).is_empty());
    }
}
