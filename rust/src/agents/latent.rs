//! The latent action memory X_b (§IV.A "Latent Action Diffusion
//! Strategy"): per (BS, slot-index) storage of the last action
//! probability iterate x_{b,n,t,0}, used to seed the next reverse
//! diffusion instead of fresh Gaussian noise. Entries are lazily
//! initialised from N(0, I) (Algorithm 1 line 1).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LatentMemory {
    b_dim: usize,
    /// x[b][n] — grown on demand up to the largest observed N_{b,t}.
    x: Vec<Vec<Vec<f32>>>,
}

impl LatentMemory {
    pub fn new(num_bs: usize, b_dim: usize) -> Self {
        Self { b_dim, x: vec![Vec::new(); num_bs] }
    }

    /// Fetch X_b[n], initialising from N(0,I) on first touch.
    pub fn get(&mut self, b: usize, n: usize, rng: &mut Rng) -> &[f32] {
        let slots = &mut self.x[b];
        while slots.len() <= n {
            let mut v = vec![0.0f32; self.b_dim];
            rng.fill_normal(&mut v);
            slots.push(v);
        }
        &slots[n][..]
    }

    /// Store X_b[n] <- x0 (Algorithm 1 line 12).
    pub fn update(&mut self, b: usize, n: usize, x0: &[f32]) {
        debug_assert_eq!(x0.len(), self.b_dim);
        if n < self.x[b].len() {
            self.x[b][n].copy_from_slice(x0);
        }
    }

    /// Reset all entries (fresh episode with re-randomisation).
    pub fn reset(&mut self) {
        for slots in &mut self.x {
            slots.clear();
        }
    }

    pub fn stored(&self, b: usize) -> usize {
        self.x[b].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_gaussian_init_then_persistent() {
        let mut mem = LatentMemory::new(2, 4);
        let mut rng = Rng::new(1);
        let first = mem.get(0, 3, &mut rng).to_vec();
        assert_eq!(mem.stored(0), 4);
        assert!(first.iter().any(|&v| v != 0.0));
        // second read returns the same values (no re-init)
        assert_eq!(mem.get(0, 3, &mut rng), &first[..]);
    }

    #[test]
    fn update_overwrites() {
        let mut mem = LatentMemory::new(1, 3);
        let mut rng = Rng::new(2);
        let _ = mem.get(0, 0, &mut rng);
        mem.update(0, 0, &[1.0, 2.0, 3.0]);
        assert_eq!(mem.get(0, 0, &mut rng), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn update_beyond_stored_is_noop() {
        let mut mem = LatentMemory::new(1, 2);
        mem.update(0, 5, &[1.0, 1.0]); // nothing stored yet
        assert_eq!(mem.stored(0), 0);
    }

    #[test]
    fn reset_clears() {
        let mut mem = LatentMemory::new(1, 2);
        let mut rng = Rng::new(3);
        let _ = mem.get(0, 0, &mut rng);
        mem.reset();
        assert_eq!(mem.stored(0), 0);
    }
}
