//! Shared plumbing for the learning agents: transition linking across
//! the s -> s_next chain of Eqn 7 (next task in the slot, else first
//! task of the next slot) and the periodic-training cadence of
//! Algorithm 1.

use super::Transition;

/// One decision awaiting its reward / successor state.
#[derive(Clone, Debug)]
pub struct Rec {
    pub s: Vec<f32>,
    pub x: Vec<f32>,
    pub a: usize,
    pub r: Option<f32>,
}

/// Links consecutive decisions of one BS into transitions per Eqn 7.
#[derive(Clone, Debug)]
pub struct TransitionLinker {
    /// Last rewarded decision of the previous slot, per BS.
    prev: Vec<Option<Rec>>,
    /// Current slot's decisions (rewards pending), per BS.
    current: Vec<Vec<Rec>>,
}

impl TransitionLinker {
    pub fn new(num_bs: usize) -> Self {
        Self { prev: vec![None; num_bs], current: vec![Vec::new(); num_bs] }
    }

    /// Register this slot's decisions for BS `b`. If the previous
    /// slot's tail decision has its reward, it links to the first new
    /// record and the completed transition is returned.
    pub fn begin(&mut self, b: usize, recs: Vec<Rec>) -> Option<Transition> {
        debug_assert!(self.current[b].is_empty(), "rewards not reported");
        let out = match (self.prev[b].take(), recs.first()) {
            (Some(p), Some(first)) if p.r.is_some() => Some(Transition {
                s: p.s,
                x: p.x,
                a: p.a,
                r: p.r.unwrap(),
                s2: first.s.clone(),
                x2: first.x.clone(),
            }),
            (p, _) => {
                self.prev[b] = p;
                None
            }
        };
        self.current[b] = recs;
        out
    }

    /// Report realized rewards for the records of the last `begin(b)`,
    /// in order. Returns all intra-slot transitions; the slot's tail
    /// record is held back until the next `begin`.
    ///
    /// An empty current slot (no `begin` since the last harvest — e.g.
    /// an agent whose forward pass failed before it could record its
    /// decisions) drops the rewards instead of panicking; a *partial*
    /// mismatch still asserts, because that means decisions and rewards
    /// went out of sync.
    pub fn rewards(&mut self, b: usize, rewards: &[f32]) -> Vec<Transition> {
        let mut recs = std::mem::take(&mut self.current[b]);
        if recs.is_empty() {
            if !rewards.is_empty() {
                log::warn!(
                    "BS {b}: dropping {} rewards with no recorded decisions",
                    rewards.len()
                );
            }
            return Vec::new();
        }
        assert_eq!(recs.len(), rewards.len(), "reward arity mismatch");
        for (rec, &r) in recs.iter_mut().zip(rewards) {
            rec.r = Some(r);
        }
        let mut out = Vec::with_capacity(recs.len().saturating_sub(1));
        for i in 0..recs.len().saturating_sub(1) {
            out.push(Transition {
                s: recs[i].s.clone(),
                x: recs[i].x.clone(),
                a: recs[i].a,
                r: recs[i].r.unwrap(),
                s2: recs[i + 1].s.clone(),
                x2: recs[i + 1].x.clone(),
            });
        }
        self.prev[b] = recs.pop();
        out
    }

    /// Drop any dangling state (episode boundary).
    pub fn reset(&mut self) {
        for p in &mut self.prev {
            *p = None;
        }
        for c in &mut self.current {
            c.clear();
        }
    }
}

/// Counts decisions and converts them into due train steps
/// (`train_every` decisions per step, capped per tick to bound
/// latency).
#[derive(Clone, Debug)]
pub struct Cadence {
    counters: Vec<usize>,
    train_every: usize,
    max_steps_per_tick: usize,
}

impl Cadence {
    pub fn new(num_bs: usize, train_every: usize) -> Self {
        Self {
            counters: vec![0; num_bs],
            train_every,
            max_steps_per_tick: 4,
        }
    }

    pub fn add(&mut self, b: usize, decisions: usize) {
        self.counters[b] += decisions;
    }

    /// Due train steps for BS `b` (consumes the counter).
    pub fn take(&mut self, b: usize) -> usize {
        if self.train_every == 0 {
            self.counters[b] = 0;
            return 0;
        }
        let steps = (self.counters[b] / self.train_every).min(self.max_steps_per_tick);
        self.counters[b] -= steps * self.train_every;
        // avoid unbounded carry-over when capped
        self.counters[b] = self.counters[b].min(self.train_every * self.max_steps_per_tick);
        steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(tag: f32) -> Rec {
        Rec { s: vec![tag], x: vec![tag * 10.0], a: tag as usize, r: None }
    }

    #[test]
    fn links_within_slot_and_across_slots() {
        let mut l = TransitionLinker::new(1);
        assert!(l.begin(0, vec![rec(1.0), rec(2.0), rec(3.0)]).is_none());
        let ts = l.rewards(0, &[-1.0, -2.0, -3.0]);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].s, vec![1.0]);
        assert_eq!(ts[0].s2, vec![2.0]);
        assert_eq!(ts[0].r, -1.0);
        assert_eq!(ts[1].x2, vec![30.0]);
        // next slot: the held-back tail links to the new head
        let cross = l.begin(0, vec![rec(4.0)]).expect("cross-slot link");
        assert_eq!(cross.s, vec![3.0]);
        assert_eq!(cross.s2, vec![4.0]);
        assert_eq!(cross.r, -3.0);
    }

    #[test]
    fn single_task_slots_only_cross_link() {
        let mut l = TransitionLinker::new(1);
        assert!(l.begin(0, vec![rec(1.0)]).is_none());
        assert!(l.rewards(0, &[-5.0]).is_empty());
        let t = l.begin(0, vec![rec(2.0)]).unwrap();
        assert_eq!((t.r, &t.s[..], &t.s2[..]), (-5.0, &[1.0][..], &[2.0][..]));
    }

    #[test]
    fn rewards_without_begin_are_dropped_not_panicking() {
        // Regression: a forward-failure fallback used to leave the slot
        // empty while the runner still reported rewards — the arity
        // assert then killed the whole run.
        let mut l = TransitionLinker::new(2);
        assert!(l.rewards(0, &[-1.0, -2.0]).is_empty());
        // the other BS is unaffected and keeps linking normally
        assert!(l.begin(1, vec![rec(1.0)]).is_none());
        assert!(l.rewards(1, &[-5.0]).is_empty());
        assert!(l.begin(1, vec![rec(2.0)]).is_some());
    }

    #[test]
    fn reset_drops_pending() {
        let mut l = TransitionLinker::new(1);
        l.begin(0, vec![rec(1.0)]);
        l.rewards(0, &[-1.0]);
        l.reset();
        assert!(l.begin(0, vec![rec(2.0)]).is_none());
    }

    #[test]
    fn cadence_counts_and_caps() {
        let mut c = Cadence::new(1, 10);
        c.add(0, 25);
        assert_eq!(c.take(0), 2);
        assert_eq!(c.take(0), 0);
        c.add(0, 5);
        assert_eq!(c.take(0), 1); // 5 leftover + 5 = 10
        // cap at 4 steps per tick
        c.add(0, 1000);
        assert_eq!(c.take(0), 4);
        // disabled training
        let mut c0 = Cadence::new(1, 0);
        c0.add(0, 100);
        assert_eq!(c0.take(0), 0);
    }
}
