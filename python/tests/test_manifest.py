"""Manifest/artifact consistency: what aot.py wrote must describe the
HLO files on disk and agree with the model's state specs. Runs against
the real artifacts/ directory when present (skips otherwise)."""

import json
import os

import pytest

from compile import model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_globals_match_model(manifest):
    assert manifest["hidden"] == model.HIDDEN
    assert manifest["temb_dim"] == model.TEMB_DIM
    assert manifest["beta_min"] == model.BETA_MIN
    assert manifest["beta_max"] == model.BETA_MAX
    assert manifest["act_batch"] == model.ACT_BATCH
    assert manifest["train_k"] == model.TRAIN_K


def test_all_files_exist(manifest):
    for name, g in manifest["graphs"].items():
        path = os.path.join(ART, g["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_expected_graph_coverage(manifest):
    names = set(manifest["graphs"])
    for b in [10, 20, 30, 40]:
        assert f"ladn_actor_fwd_b{b}_i5" in names
        assert f"ladn_train_b{b}_i5" in names
        assert f"sac_actor_fwd_b{b}" in names
        assert f"sac_train_b{b}" in names
        assert f"dqn_fwd_b{b}" in names
        assert f"dqn_train_b{b}" in names
    for i in [1, 2, 3, 7, 10]:
        assert f"ladn_actor_fwd_b20_i{i}" in names
        assert f"ladn_train_b20_i{i}" in names
    assert "ladn_train_b20_i5_noauto" in names
    assert "ladn_train_b20_i5_paperloss" in names
    assert "genmodel_encode" in names
    assert "genmodel_step" in names


def test_train_state_specs_match_model(manifest):
    for b in [10, 20, 30, 40]:
        g = manifest["graphs"][f"ladn_train_b{b}_i5"]
        spec = model.lad_state_spec(b)
        assert g["meta"]["state_len"] == len(spec)
        for (name, shape), ispec in zip(spec, g["inputs"]):
            assert ispec["name"] == name
            assert tuple(ispec["shape"]) == tuple(shape)
        # outputs = new state + metrics
        assert len(g["outputs"]) == len(spec) + 1
        assert g["outputs"][-1]["name"] == "metrics"


def test_fwd_graph_param_prefix(manifest):
    g = manifest["graphs"]["ladn_actor_fwd_b20_i5"]
    state_len = g["meta"]["state_len"]
    assert state_len == 6
    for ispec in g["inputs"][:state_len]:
        assert ispec["name"].startswith("actor.")
    assert [i["name"] for i in g["inputs"][state_len:]] == ["x_i", "s", "noise"]


def test_hlo_files_are_text_modules(manifest):
    for name, g in list(manifest["graphs"].items())[:6]:
        with open(os.path.join(ART, g["file"])) as f:
            head = f.read(200)
        assert "HloModule" in head, name
