"""Properties of the reverse-diffusion machinery (Theorem 2 schedule,
timestep embedding, actor forward)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


@pytest.mark.parametrize("i_steps", [1, 2, 3, 5, 7, 10])
def test_beta_schedule_shapes_and_ranges(i_steps):
    beta, lam, lam_bar, beta_tilde = model.beta_schedule(i_steps)
    beta, lam, lam_bar, beta_tilde = map(np.array, (beta, lam, lam_bar, beta_tilde))
    assert beta.shape == (i_steps,)
    assert ((beta > 0) & (beta < 1)).all()
    # beta_i increases with i (more noise earlier in the forward chain).
    assert (np.diff(beta) > 0).all() or i_steps == 1
    np.testing.assert_allclose(lam, 1.0 - beta, rtol=1e-6)
    # cumulative product decreases monotonically.
    assert (np.diff(lam_bar) < 0).all() or i_steps == 1
    # first posterior variance is exactly 0 (deterministic final step).
    assert beta_tilde[0] == 0.0
    assert (beta_tilde >= 0).all()


def test_beta_schedule_matches_closed_form():
    i_steps = 5
    beta = np.array(model.beta_schedule(i_steps)[0])
    for i in range(1, i_steps + 1):
        want = 1.0 - math.exp(
            -model.BETA_MIN / i_steps
            - (2 * i - 1) / (2 * i_steps**2) * (model.BETA_MAX - model.BETA_MIN)
        )
        np.testing.assert_allclose(beta[i - 1], want, rtol=1e-5)


def test_timestep_embedding_distinct_and_bounded():
    embs = [np.array(model.timestep_embedding(i)) for i in range(1, 11)]
    for e in embs:
        assert e.shape == (model.TEMB_DIM,)
        assert (np.abs(e) <= 1.0 + 1e-6).all()
    for i in range(len(embs) - 1):
        assert not np.allclose(embs[i], embs[i + 1])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), i_steps=st.sampled_from([1, 3, 5, 10]))
def test_actor_fwd_is_simplex(seed, i_steps):
    b_dim, n = 20, 64
    s_dim = model.state_dim(b_dim)
    key = jax.random.PRNGKey(seed)
    p = model.mlp_init(key, b_dim + model.TEMB_DIM + s_dim, b_dim)
    x = jax.random.normal(key, (n, b_dim))
    s = jax.random.normal(key, (n, s_dim))
    noise = jax.random.normal(key, (i_steps, n, b_dim))
    x0, pi = model.actor_fwd(p, x, s, noise, i_steps, use_kernel=False)
    pi = np.array(pi)
    assert np.isfinite(np.array(x0)).all()
    np.testing.assert_allclose(pi.sum(axis=1), 1.0, atol=1e-5)
    assert (pi >= 0).all()


def test_actor_fwd_kernel_matches_jnp_path():
    """The request-path (Pallas) and train-path (jnp) actors must agree."""
    b_dim, n, i_steps = 20, 128, 5
    s_dim = model.state_dim(b_dim)
    key = jax.random.PRNGKey(11)
    p = model.mlp_init(key, b_dim + model.TEMB_DIM + s_dim, b_dim)
    x = jax.random.normal(key, (n, b_dim))
    s = jax.random.normal(key, (n, s_dim))
    noise = jax.random.normal(key, (i_steps, n, b_dim))
    xk, pk = model.actor_fwd(p, x, s, noise, i_steps, use_kernel=True)
    xj, pj = model.actor_fwd(p, x, s, noise, i_steps, use_kernel=False)
    np.testing.assert_allclose(np.array(xk), np.array(xj), atol=1e-4)
    np.testing.assert_allclose(np.array(pk), np.array(pj), atol=1e-5)


def test_actor_fwd_latent_conditioning_matters():
    """Different starting latents must yield different x_0 — the latent
    action memory is the paper's core mechanism."""
    b_dim, n, i_steps = 20, 32, 5
    s_dim = model.state_dim(b_dim)
    key = jax.random.PRNGKey(5)
    p = model.mlp_init(key, b_dim + model.TEMB_DIM + s_dim, b_dim)
    s = jax.random.normal(key, (n, s_dim))
    noise = jnp.zeros((i_steps, n, b_dim))
    x_a = jax.random.normal(jax.random.PRNGKey(1), (n, b_dim))
    x_b = jax.random.normal(jax.random.PRNGKey(2), (n, b_dim))
    x0a, _ = model.actor_fwd(p, x_a, s, noise, i_steps, use_kernel=False)
    x0b, _ = model.actor_fwd(p, x_b, s, noise, i_steps, use_kernel=False)
    assert not np.allclose(np.array(x0a), np.array(x0b), atol=1e-3)
