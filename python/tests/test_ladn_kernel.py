"""Layer-1 correctness: the fused epsilon-MLP Pallas kernel vs jnp oracle.

Hypothesis sweeps action dims, batch sizes (multiples of the row block),
and value scales; assert_allclose against ref.eps_mlp_ref is THE core
correctness signal for the kernel on the request path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ladn_denoise, ref


def make_params(key, b_dim, s_dim):
    return model.mlp_init(key, b_dim + model.TEMB_DIM + s_dim, b_dim)


def run_both(key, n, b_dim, scale=1.0, step=3):
    s_dim = model.state_dim(b_dim)
    p = make_params(key, b_dim, s_dim)
    kx, ks = jax.random.split(key)
    x = jax.random.normal(kx, (n, b_dim)) * scale
    s = jax.random.normal(ks, (n, s_dim)) * scale
    temb = model.timestep_embedding(step)
    args = (x, temb, s, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"])
    return ladn_denoise.eps_mlp(*args), ref.eps_mlp_ref(*args)


@pytest.mark.parametrize("b_dim", [10, 20, 30, 40])
def test_kernel_matches_ref_across_bdims(b_dim):
    got, want = run_both(jax.random.PRNGKey(b_dim), 128, b_dim)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)


@pytest.mark.parametrize("n", [32, 64, 96, 128])
def test_kernel_matches_ref_across_batches(n):
    got, want = run_both(jax.random.PRNGKey(n), n, 20)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)


def test_kernel_rejects_unaligned_batch():
    with pytest.raises(ValueError, match="row block"):
        run_both(jax.random.PRNGKey(0), 33, 20)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b_dim=st.sampled_from([4, 10, 20, 40]),
    blocks=st.integers(1, 4),
    scale=st.floats(0.01, 50.0),
    step=st.integers(1, 10),
)
def test_kernel_matches_ref_hypothesis(seed, b_dim, blocks, scale, step):
    n = blocks * ladn_denoise.ROW_BLOCK
    got, want = run_both(jax.random.PRNGKey(seed), n, b_dim, scale, step)
    np.testing.assert_allclose(
        np.array(got), np.array(want), atol=1e-4 * max(scale, 1.0)
    )


def test_kernel_zero_input_gives_bias_path():
    """x=s=0, temb path only: output must equal the pure-bias forward."""
    b_dim, s_dim = 20, 22
    p = make_params(jax.random.PRNGKey(7), b_dim, s_dim)
    n = 32
    x = jnp.zeros((n, b_dim))
    s = jnp.zeros((n, s_dim))
    temb = model.timestep_embedding(1)
    got = ladn_denoise.eps_mlp(
        x, temb, s, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"]
    )
    # every row identical
    assert np.allclose(np.array(got - got[0]), 0.0, atol=1e-6)
