"""Train-step graphs: state round-trip, finiteness, learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

B, I, K = 20, 5, model.TRAIN_K
S = model.state_dim(B)


def lad_batch(key):
    ks = jax.random.split(key, 8)
    return {
        "s": jax.random.uniform(ks[0], (K, S)),
        "x": jax.random.normal(ks[1], (K, B)),
        "a": jax.random.randint(ks[2], (K,), 0, B),
        "r": -jax.random.uniform(ks[3], (K,)) * 2.0,
        "s2": jax.random.uniform(ks[4], (K, S)),
        "x2": jax.random.normal(ks[5], (K, B)),
        "noise": jax.random.normal(ks[6], (I, K, B)),
        "noise2": jax.random.normal(ks[7], (I, K, B)),
    }


def test_state_pack_unpack_roundtrip():
    spec = model.lad_state_spec(B)
    tree = model.lad_state_init(jax.random.PRNGKey(0), B)
    flat = model.pack_state(spec, tree)
    assert len(flat) == len(spec)
    for (name, shape), t in zip(spec, flat):
        assert tuple(t.shape) == tuple(shape), name
    tree2 = model.unpack_state(spec, flat)
    flat2 = model.pack_state(spec, tree2)
    for a, b in zip(flat, flat2):
        assert a is b


@pytest.mark.parametrize("form", ["standard", "paper"])
def test_lad_train_step_finite_and_advances(form):
    spec = model.lad_state_spec(B)
    flat = model.pack_state(spec, model.lad_state_init(jax.random.PRNGKey(1), B))
    batch = lad_batch(jax.random.PRNGKey(2))
    fn = jax.jit(lambda f, b: model.lad_train_step(f, b, B, I, actor_loss_form=form))
    new, mets = fn(flat, batch)
    mets = np.array(mets)
    assert np.isfinite(mets).all()
    for t in new:
        assert np.isfinite(np.array(t)).all()
    # step counter advanced
    assert float(new[-1]) == 1.0
    # parameters actually moved
    assert not np.allclose(np.array(new[0]), np.array(flat[0]))


def test_lad_critic_loss_decreases_on_fixed_batch():
    """Repeated updates on one batch must reduce the critic loss — the
    minimal learning-signal sanity check."""
    spec = model.lad_state_spec(B)
    flat = model.pack_state(spec, model.lad_state_init(jax.random.PRNGKey(3), B))
    batch = lad_batch(jax.random.PRNGKey(4))
    fn = jax.jit(lambda f, b: model.lad_train_step(f, b, B, I))
    losses = []
    for _ in range(60):
        flat, mets = fn(flat, batch)
        losses.append(float(mets[0]))
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_lad_alpha_freezes_without_autotune():
    spec = model.lad_state_spec(B)
    flat = model.pack_state(spec, model.lad_state_init(jax.random.PRNGKey(5), B))
    batch = lad_batch(jax.random.PRNGKey(6))
    fn = jax.jit(
        lambda f, b: model.lad_train_step(f, b, B, I, alpha_autotune=False)
    )
    names = [n for n, _ in spec]
    ia = names.index("log_alpha")
    before = float(flat[ia])
    for _ in range(5):
        flat, _ = fn(flat, batch)
    assert float(flat[ia]) == before


def test_sac_train_step_finite():
    spec = model.sac_state_spec(B)
    s_dim = model.state_dim(B)
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    a_shapes = model.mlp_shapes(s_dim, B)
    actor = model.mlp_init(ks[0], s_dim, B)
    c1 = model.mlp_init(ks[1], s_dim, B)
    c2 = model.mlp_init(ks[2], s_dim, B)
    tree = {
        "actor": actor, "c1": c1, "c2": c2,
        "t1": dict(c1), "t2": dict(c2),
        "m_actor": model.zeros_like_tree(actor),
        "v_actor": model.zeros_like_tree(actor),
        "m_c1": model.zeros_like_tree(c1), "v_c1": model.zeros_like_tree(c1),
        "m_c2": model.zeros_like_tree(c2), "v_c2": model.zeros_like_tree(c2),
        "log_alpha": jnp.asarray(np.log(0.05), jnp.float32),
        "m_alpha": jnp.asarray(0.0), "v_alpha": jnp.asarray(0.0),
        "step": jnp.asarray(0.0),
    }
    flat = model.pack_state(spec, tree)
    batch = {
        "s": jax.random.uniform(ks[3], (K, S)),
        "a": jax.random.randint(ks[3], (K,), 0, B),
        "r": -jax.random.uniform(ks[4], (K,)),
        "s2": jax.random.uniform(ks[4], (K, S)),
    }
    new, mets = jax.jit(lambda f, b: model.sac_train_step(f, b, B))(flat, batch)
    assert np.isfinite(np.array(mets)).all()
    assert float(new[-1]) == 1.0


def test_dqn_train_step_reduces_loss():
    spec = model.dqn_state_spec(B)
    s_dim = model.state_dim(B)
    q = model.mlp_init(jax.random.PRNGKey(8), s_dim, B)
    tree = {
        "q": q, "t": dict(q),
        "m_q": model.zeros_like_tree(q), "v_q": model.zeros_like_tree(q),
        "step": jnp.asarray(0.0),
    }
    flat = model.pack_state(spec, tree)
    key = jax.random.PRNGKey(9)
    batch = {
        "s": jax.random.uniform(key, (K, S)),
        "a": jax.random.randint(key, (K,), 0, B),
        "r": -jax.random.uniform(key, (K,)),
        "s2": jax.random.uniform(key, (K, S)),
    }
    fn = jax.jit(lambda f, b: model.dqn_train_step(f, b, B))
    losses = []
    for _ in range(50):
        flat, mets = fn(flat, batch)
        losses.append(float(mets[0]))
    assert losses[-1] < losses[0]
