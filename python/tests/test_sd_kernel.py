"""Layer-1 correctness: the conditioned latent-denoise kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, sd_step


def run_both(key, h, w, d, a=0.9, b=0.3):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    latent = jax.random.normal(k1, (h, w))
    cond = jax.random.normal(k2, (d,))
    wm = jax.random.normal(k3, (w, w)) / np.sqrt(w)
    um = jax.random.normal(k4, (d, w)) / np.sqrt(d)
    got = sd_step.latent_step(latent, cond, wm, um, jnp.float32(a), jnp.float32(b))
    want = ref.latent_step_ref(latent, cond, wm, um, a, b)
    return got, want


@pytest.mark.parametrize("h", [16, 32, 64, 128])
def test_latent_step_matches_ref_sizes(h):
    got, want = run_both(jax.random.PRNGKey(h), h, 64, 64)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)


def test_latent_step_rejects_unaligned_rows():
    with pytest.raises(ValueError, match="divisible"):
        run_both(jax.random.PRNGKey(0), 17, 64, 64)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    w=st.sampled_from([32, 64]),
    a=st.floats(0.0, 1.0),
    b=st.floats(0.0, 1.0),
)
def test_latent_step_hypothesis(seed, blocks, w, a, b):
    h = blocks * sd_step.ROW_BLOCK
    got, want = run_both(jax.random.PRNGKey(seed), h, w, 64, a, b)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)


def test_latent_step_identity_when_b_zero():
    """b=0 must return a*latent exactly (tanh path disabled)."""
    key = jax.random.PRNGKey(3)
    latent = jax.random.normal(key, (32, 64))
    cond = jnp.ones((64,))
    wm = jnp.eye(64)
    um = jnp.zeros((64, 64))
    got = sd_step.latent_step(latent, cond, wm, um, jnp.float32(0.5),
                              jnp.float32(0.0))
    np.testing.assert_allclose(np.array(got), 0.5 * np.array(latent), atol=1e-6)


def test_genmodel_step_contracts_latent():
    """Repeated genmodel steps must keep the latent bounded (stability of
    the serving loop: a*latent + b*tanh(...) with a<1, |tanh|<=1)."""
    latent = jax.random.normal(jax.random.PRNGKey(1), (64, 64)) * 3.0
    cond = model.genmodel_encode(jnp.arange(16, dtype=jnp.int32))
    for z in range(15, 0, -1):
        latent = model.genmodel_step(latent, cond, jnp.float32(z))
    assert np.isfinite(np.array(latent)).all()
    assert np.abs(np.array(latent)).max() < 10.0
