import os
import sys

# Allow `from compile import model` when pytest is invoked from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
