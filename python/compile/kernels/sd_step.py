"""Conditioned latent-denoise kernel for the toy generation model (L1).

DEdgeAI workers serve a scaled-down stand-in for reSD3-m: a latent
``[H, W]`` image refined by ``z_n`` conditioned denoising steps (the
paper's workload model — cost ∝ number of denoising steps). Each step is

    latent' = a * latent + b * tanh(latent @ W + cond @ U)

fused into one Pallas kernel.

TPU mapping: the latent is tiled into ``[ROW_BLOCK, W]`` row bands
(BlockSpec over the grid's single axis); the ``[W, W]`` mixing matrix and
the pre-projected conditioning row stay VMEM-resident for the whole
grid. ``interpret=True`` for CPU-PJRT execution (see ladn_denoise.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 16


def _latent_step_kernel(lat_ref, proj_ref, w_ref, ab_ref, o_ref):
    lat = lat_ref[...]                       # [RB, W]
    a = ab_ref[0, 0]
    b = ab_ref[0, 1]
    mix = jnp.dot(lat, w_ref[...]) + proj_ref[...]   # [RB,W] + [1,W]
    o_ref[...] = a * lat + b * jnp.tanh(mix)


@functools.partial(jax.jit, static_argnames=("row_block",))
def latent_step(latent, cond, w, u, a, b, row_block=ROW_BLOCK):
    """One conditioned denoise step over the latent image.

    Args match ``ref.latent_step_ref``. ``cond @ u`` is computed once
    outside the kernel (it is row-invariant) and broadcast in VMEM.
    """
    h, wdim = latent.shape
    if h % row_block != 0:
        raise ValueError(f"latent rows {h} not divisible by {row_block}")
    proj = (cond @ u)[None, :]                      # [1, W]
    ab = jnp.stack([a, b]).reshape(1, 2).astype(jnp.float32)

    grid = (h // row_block,)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        _latent_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, wdim), lambda i: (i, 0)),  # latent
            full((1, wdim)),                                    # proj
            full((wdim, wdim)),                                 # w
            full((1, 2)),                                       # a,b
        ],
        out_specs=pl.BlockSpec((row_block, wdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wdim), jnp.float32),
        interpret=True,
    )(latent, proj, w, ab)
