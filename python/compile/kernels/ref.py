"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
checks kernel-vs-ref numerics (see python/tests/) and the rust native
backend mirrors exactly this math, so the chain

    rust nn (native)  ==  jnp ref  ==  pallas kernel  ==  AOT HLO

is closed by tests at every link.
"""

import jax.numpy as jnp


def eps_mlp_ref(x, temb, s, w1, b1, w2, b2, w3, b3):
    """Epsilon-network of the LADN actor: a 2-hidden-layer ReLU MLP over
    the concatenation ``[x, temb, s]``.

    Args:
      x:    [N, B]  current diffused action-probability iterate.
      temb: [E]     sinusoidal timestep embedding (shared by all rows).
      s:    [N, S]  system state (Eqn 6 of the paper).
      w1:   [B+E+S, H], b1: [H]
      w2:   [H, H],     b2: [H]
      w3:   [H, B],     b3: [B]

    Returns:
      eps: [N, B] predicted noise.
    """
    n = x.shape[0]
    temb_rows = jnp.broadcast_to(temb[None, :], (n, temb.shape[0]))
    h = jnp.concatenate([x, temb_rows, s], axis=1)
    h = jnp.maximum(h @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    return h @ w3 + b3


def latent_step_ref(latent, cond, w, u, a, b):
    """One conditioned denoising step of the toy generation model.

    ``latent' = a * latent + b * tanh(latent @ w + (cond @ u))``

    Args:
      latent: [H, W] latent image.
      cond:   [D]    text-conditioning vector.
      w:      [W, W] mixing weights.
      u:      [D, W] conditioning projection.
      a, b:   scalars (retention / update rates).
    """
    proj = cond @ u
    return a * latent + b * jnp.tanh(latent @ w + proj[None, :])
