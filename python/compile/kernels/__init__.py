"""Layer-1 Pallas kernels (build-time only).

Two kernels implement the hot compute of the system:

- ``ladn_denoise.eps_mlp`` — the fused epsilon-network of the LADN actor
  (one reverse-diffusion denoising step of the scheduling policy).
- ``sd_step.latent_step`` — one conditioned denoising step of the toy
  latent-diffusion generation model served by DEdgeAI workers.

Both are lowered with ``interpret=True`` so the resulting HLO runs on any
PJRT backend (the rust CPU client in particular). ``ref.py`` holds the
pure-jnp oracles used by pytest/hypothesis.
"""

from . import ladn_denoise, ref, sd_step  # noqa: F401
