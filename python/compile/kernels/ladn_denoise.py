"""Fused epsilon-network kernel of the LADN actor (Layer 1).

One reverse-diffusion denoising step of the scheduling policy evaluates
``eps = MLP(concat(x_i, temb(i), s))`` for a batch of tasks. This kernel
fuses the concat + 3 matmuls + 2 ReLUs into a single Pallas call so the
whole step stays resident in VMEM on a real TPU.

TPU mapping (the paper's testbed is CUDA; see DESIGN.md
§Hardware-Adaptation): instead of a threadblock-per-row GPU layout, we
tile the batch dimension into row blocks via ``BlockSpec`` — each grid
step streams one ``[RB, B]`` x-block plus its ``[RB, S]`` state block
from HBM to VMEM while all weight matrices (≤ (B+E+S)·H + H·H + H·B
floats ≈ 6 KB at B=20, H=20) stay VMEM-resident across the grid. The
concat is algebraically split: ``concat(x,t,s) @ W1`` is computed as
``x @ W1x + t @ W1t + s @ W1s`` (row slices of W1), which avoids
materializing the concatenated block and feeds the MXU three small
back-to-back matmuls.

Run with ``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 32 rows × (B+S+E) cols ≈ 8 KB at B=20 — far below
# VMEM; chosen so the padded act batch (128) divides evenly.
ROW_BLOCK = 32


def _eps_mlp_kernel(x_ref, temb_ref, s_ref, w1x_ref, w1t_ref, w1s_ref,
                    b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """Kernel body: one row-block of the fused epsilon MLP."""
    x = x_ref[...]            # [RB, B]
    s = s_ref[...]            # [RB, S]
    temb = temb_ref[...]      # [1, E]
    # concat(x, temb, s) @ W1 == x@W1x + temb@W1t + s@W1s (W1 row slices).
    h = (
        jnp.dot(x, w1x_ref[...])
        + jnp.dot(temb, w1t_ref[...])  # [1,H] broadcasts over rows
        + jnp.dot(s, w1s_ref[...])
        + b1_ref[...]
    )
    h = jnp.maximum(h, 0.0)
    h = jnp.maximum(jnp.dot(h, w2_ref[...]) + b2_ref[...], 0.0)
    o_ref[...] = jnp.dot(h, w3_ref[...]) + b3_ref[...]


@functools.partial(jax.jit, static_argnames=("row_block",))
def eps_mlp(x, temb, s, w1, b1, w2, b2, w3, b3, row_block=ROW_BLOCK):
    """Fused epsilon network over a task batch.

    Args match ``ref.eps_mlp_ref``; ``w1`` is the full ``[B+E+S, H]``
    first-layer weight — sliced here into the x/temb/s row bands.

    The batch dimension N must be divisible by ``row_block`` (callers pad
    to the fixed act batch); weights are broadcast to every grid step.
    """
    n, b_dim = x.shape
    e_dim = temb.shape[0]
    s_dim = s.shape[1]
    h_dim = w1.shape[1]
    if n % row_block != 0:
        raise ValueError(f"batch {n} not divisible by row block {row_block}")
    w1x = w1[:b_dim]
    w1t = w1[b_dim:b_dim + e_dim]
    w1s = w1[b_dim + e_dim:]
    temb2 = temb[None, :]

    grid = (n // row_block,)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    rows = lambda cols: pl.BlockSpec((row_block, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _eps_mlp_kernel,
        grid=grid,
        in_specs=[
            rows(b_dim),                 # x
            full((1, e_dim)),            # temb
            rows(s_dim),                 # s
            full((b_dim, h_dim)),        # w1x
            full((e_dim, h_dim)),        # w1t
            full((s_dim, h_dim)),        # w1s
            full((h_dim,)),              # b1
            full((h_dim, h_dim)),        # w2
            full((h_dim,)),              # b2
            full((h_dim, b_dim)),        # w3
            full((b_dim,)),              # b3
        ],
        out_specs=rows(b_dim),
        out_shape=jax.ShapeDtypeStruct((n, b_dim), jnp.float32),
        interpret=True,
    )(x, temb2, s, w1x, w1t, w1s, b1, w2, b2, w3, b3)
