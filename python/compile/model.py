"""Layer-2 JAX compute graphs (build-time only).

Everything the rust coordinator executes at run time is defined here and
AOT-lowered by ``aot.py`` to HLO text:

- the LADN actor forward pass (Theorem 2 reverse diffusion, calling the
  Layer-1 Pallas kernel for the fused epsilon network),
- the LAD-TS / SAC-TS / DQN-TS train steps (losses of Eqns 14-17, full
  Adam state threaded through the graph so rust round-trips the train
  state as a flat list of tensors),
- the toy generation model (text encode + conditioned latent denoise)
  served by DEdgeAI workers.

Conventions shared with the rust side (see rust/src/runtime/):
- all floats are f32, action indices are i32;
- train state is a *flat ordered list* of tensors described by
  ``lad_state_spec`` / ``sac_state_spec`` / ``dqn_state_spec``; the same
  order is written to artifacts/manifest.json;
- stochasticity enters only through explicit ``noise`` inputs sampled by
  the rust PRNG, keeping graphs deterministic and replayable.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import ladn_denoise, ref

# ---------------------------------------------------------------------------
# Model hyper-parameters (Table IV of the paper + DESIGN.md calibration).
# ---------------------------------------------------------------------------
HIDDEN = 20          # two hidden layers of 20 neurons (Table IV)
TEMB_DIM = 16        # sinusoidal timestep-embedding width
BETA_MIN = 0.1       # VP-SDE schedule bounds (DDPM / D2SAC convention)
BETA_MAX = 10.0
ACT_BATCH = 128      # padded decision batch (N_b,t <= 70 in all sweeps)
TRAIN_K = 64         # SGD batch size K (Table IV)
GAMMA = 0.95         # reward decay (Table IV)
TAU = 0.005          # soft-update weight (Table IV)
LR_ACTOR = 1e-4      # eta_a
LR_CRITIC = 1e-3     # eta_c
LR_ALPHA = 3e-4      # eta_alpha
TARGET_ENTROPY = -1.0  # H~ (Table IV); Eqn 16 makes -H~ the effective target
LOG_ALPHA_MIN = math.log(1e-3)
LOG_ALPHA_MAX = math.log(5.0)
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
# Per-step clamp on the diffusion iterate (the standard DDPM x-clip, cf.
# D2SAC's implementation). Without it the LAD feedback loop X_b[n] <- x_0
# -> next x_I diverges: the reverse chain amplifies by 1/sqrt(lam_bar) ~=
# 12x per pass. +-5 keeps softmax logits expressive (ratio e^10) while
# bounding the latent memory.
X_CLIP = 5.0

# Toy generation model (the reSD3-m stand-in; see DESIGN.md substitutions).
GEN_LATENT = 64      # latent image is [64, 64]
GEN_COND = 64        # text-conditioning width
GEN_VOCAB = 256      # byte-level toy tokenizer
GEN_TOKENS = 16      # fixed prompt length (pad/truncate)


def state_dim(b_dim: int) -> int:
    """State s = [d_n, rho_n*z_n, q_{t-1,1..B}] (Eqn 6)."""
    return 2 + b_dim


# ---------------------------------------------------------------------------
# Diffusion schedule (Theorem 2).
# ---------------------------------------------------------------------------

def beta_schedule(i_steps: int):
    """VP-SDE discrete betas: beta_i = 1 - exp(-bmin/I - (2i-1)/(2I^2)(bmax-bmin)).

    Returns (beta[I], lam[I], lam_bar[I], beta_tilde[I]) indexed by
    i-1 for i in 1..I. ``beta_tilde_1 = 0`` (lam_bar_0 == 1), making the
    final denoising step deterministic — matching DDPM and the paper.
    """
    i = jnp.arange(1, i_steps + 1, dtype=jnp.float32)
    beta = 1.0 - jnp.exp(
        -BETA_MIN / i_steps
        - (2.0 * i - 1.0) / (2.0 * i_steps**2) * (BETA_MAX - BETA_MIN)
    )
    lam = 1.0 - beta
    lam_bar = jnp.cumprod(lam)
    lam_bar_prev = jnp.concatenate([jnp.ones((1,), jnp.float32), lam_bar[:-1]])
    beta_tilde = (1.0 - lam_bar_prev) / (1.0 - lam_bar) * beta
    return beta, lam, lam_bar, beta_tilde


def timestep_embedding(i: int, dim: int = TEMB_DIM):
    """Sinusoidal embedding of denoise-step index i (static python int)."""
    half = dim // 2
    freqs = jnp.exp(
        -math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = i * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


# ---------------------------------------------------------------------------
# MLP primitives. The epsilon net runs through the Pallas kernel on the
# inference graph; train graphs use the jnp reference (identical math,
# autodiff-friendly).
# ---------------------------------------------------------------------------

def mlp_init(key, din: int, dout: int, hidden: int = HIDDEN):
    """Uniform Kaiming-style init, mirrored bit-for-bit by rust nn::init
    (rust re-derives init natively; only the *forward* math must match)."""
    k1, k2, k3 = jax.random.split(key, 3)

    def layer(k, i, o):
        bound = 1.0 / math.sqrt(i)
        return jax.random.uniform(k, (i, o), jnp.float32, -bound, bound)

    return {
        "w1": layer(k1, din, hidden), "b1": jnp.zeros((hidden,)),
        "w2": layer(k2, hidden, hidden), "b2": jnp.zeros((hidden,)),
        "w3": layer(k3, hidden, dout), "b3": jnp.zeros((dout,)),
    }


def mlp_apply(p, x):
    h = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
    h = jnp.maximum(h @ p["w2"] + p["b2"], 0.0)
    return h @ p["w3"] + p["b3"]


def eps_apply(p, x, temb, s, use_kernel: bool):
    """Epsilon network eps_theta(x_i, i, s): Pallas kernel or jnp ref."""
    if use_kernel:
        return ladn_denoise.eps_mlp(
            x, temb, s, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"]
        )
    return ref.eps_mlp_ref(
        x, temb, s, p["w1"], p["b1"], p["w2"], p["b2"], p["w3"], p["b3"]
    )


# ---------------------------------------------------------------------------
# LADN actor forward (reverse diffusion, Theorem 2).
# ---------------------------------------------------------------------------

def actor_fwd(params, x_i, s, noise, i_steps: int, use_kernel: bool):
    """Reverse-diffuse the latent action probability.

    Args:
      params: epsilon-MLP params (din = B + TEMB_DIM + S).
      x_i:   [N, B] starting iterate — the stored latent action
             probability X_b[n] for LAD-TS, fresh N(0,I) for D2SAC-TS.
      s:     [N, S] system state.
      noise: [I, N, B] pre-sampled N(0,I) injected per step (Eqn 10's
             eps term); pass zeros for deterministic evaluation.
      i_steps: number of denoising steps I (static).
      use_kernel: route eps through the Pallas kernel (inference graph)
             or the jnp ref (train graph; autodiff-safe).

    Returns:
      (x_0 [N,B], pi [N,B]) — final iterate and softmax action probs.
    """
    beta, lam, lam_bar, beta_tilde = beta_schedule(i_steps)
    x = x_i
    for i in range(i_steps, 0, -1):
        idx = i - 1
        temb = timestep_embedding(i)
        eps = eps_apply(params, x, temb, s, use_kernel)
        mean = (x - beta[idx] / jnp.sqrt(1.0 - lam_bar[idx]) * eps) / jnp.sqrt(
            lam[idx]
        )
        # Paper's Eqn 10 injects (beta_tilde_i / 2) * eps_noise; the
        # iterate is clamped per step (see X_CLIP above).
        x = mean + (beta_tilde[idx] / 2.0) * noise[i_steps - i]
        # Smooth clamp: X_CLIP * tanh(x / X_CLIP). A hard clip zeroes
        # actor gradients once the 1/sqrt(lam_bar) amplification
        # saturates coordinates (which it does for most), freezing the
        # policy; tanh keeps the iterate bounded with live gradients.
        x = X_CLIP * jnp.tanh(x / X_CLIP)
    pi = jax.nn.softmax(x, axis=-1)
    return x, pi


def sac_actor_fwd(params, s):
    """Categorical MLP actor of the SAC-TS baseline."""
    logits = mlp_apply(params, s)
    return logits, jax.nn.softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# Adam (explicitly threaded; rust owns the flat state between calls).
# ---------------------------------------------------------------------------

def adam_update(params, grads, m, v, step, lr):
    """One Adam step over a dict of tensors. ``step`` is the *new* count."""
    b1t = 1.0 - ADAM_B1**step
    b2t = 1.0 - ADAM_B2**step
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        new_v[k] = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        mhat = new_m[k] / b1t
        vhat = new_v[k] / b2t
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new_p, new_m, new_v


def zeros_like_tree(p):
    return {k: jnp.zeros_like(v) for k, v in p.items()}


# ---------------------------------------------------------------------------
# Train-state layout. Rust reconstructs these dicts from a flat tensor
# list; the spec below *is* the contract (also emitted to manifest.json).
# ---------------------------------------------------------------------------

MLP_KEYS = ["w1", "b1", "w2", "b2", "w3", "b3"]


def mlp_shapes(din, dout, hidden=HIDDEN):
    return {
        "w1": (din, hidden), "b1": (hidden,),
        "w2": (hidden, hidden), "b2": (hidden,),
        "w3": (hidden, dout), "b3": (dout,),
    }


def _spec_block(prefix, shapes):
    return [(f"{prefix}.{k}", shapes[k]) for k in MLP_KEYS]


def lad_state_spec(b_dim: int):
    """Flat train-state layout for LAD-TS / D2SAC-TS (shared graphs)."""
    s_dim = state_dim(b_dim)
    eps_shapes = mlp_shapes(b_dim + TEMB_DIM + s_dim, b_dim)
    q_shapes = mlp_shapes(s_dim, b_dim)
    spec = []
    spec += _spec_block("actor", eps_shapes)
    for net in ["c1", "c2", "t1", "t2"]:
        spec += _spec_block(net, q_shapes)
    for opt, shapes in [("actor", eps_shapes), ("c1", q_shapes), ("c2", q_shapes)]:
        spec += _spec_block(f"m_{opt}", shapes)
        spec += _spec_block(f"v_{opt}", shapes)
    spec += [("log_alpha", ()), ("m_alpha", ()), ("v_alpha", ()), ("step", ())]
    return spec


def sac_state_spec(b_dim: int):
    """Flat train-state layout for SAC-TS (actor is a plain MLP on s)."""
    s_dim = state_dim(b_dim)
    a_shapes = mlp_shapes(s_dim, b_dim)
    q_shapes = mlp_shapes(s_dim, b_dim)
    spec = []
    spec += _spec_block("actor", a_shapes)
    for net in ["c1", "c2", "t1", "t2"]:
        spec += _spec_block(net, q_shapes)
    for opt, shapes in [("actor", a_shapes), ("c1", q_shapes), ("c2", q_shapes)]:
        spec += _spec_block(f"m_{opt}", shapes)
        spec += _spec_block(f"v_{opt}", shapes)
    spec += [("log_alpha", ()), ("m_alpha", ()), ("v_alpha", ()), ("step", ())]
    return spec


def dqn_state_spec(b_dim: int):
    s_dim = state_dim(b_dim)
    q_shapes = mlp_shapes(s_dim, b_dim)
    spec = []
    spec += _spec_block("q", q_shapes)
    spec += _spec_block("t", q_shapes)
    spec += _spec_block("m_q", q_shapes)
    spec += _spec_block("v_q", q_shapes)
    spec += [("step", ())]
    return spec


def pack_state(spec, tree):
    """dict-of-dicts -> flat tensor list in spec order."""
    flat = []
    for name, _shape in spec:
        parts = name.split(".")
        node = tree
        for p in parts:
            node = node[p]
        flat.append(node)
    return flat


def unpack_state(spec, flat):
    """flat tensor list -> nested dict per spec."""
    tree = {}
    for (name, _shape), t in zip(spec, flat):
        parts = name.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = t
    return tree


def lad_state_init(key, b_dim: int):
    """Reference initializer (used by python tests; rust has its own)."""
    s_dim = state_dim(b_dim)
    ks = jax.random.split(key, 5)
    actor = mlp_init(ks[0], b_dim + TEMB_DIM + s_dim, b_dim)
    c1 = mlp_init(ks[1], s_dim, b_dim)
    c2 = mlp_init(ks[2], s_dim, b_dim)
    tree = {
        "actor": actor, "c1": c1, "c2": c2,
        "t1": {k: v for k, v in c1.items()},
        "t2": {k: v for k, v in c2.items()},
        "m_actor": zeros_like_tree(actor), "v_actor": zeros_like_tree(actor),
        "m_c1": zeros_like_tree(c1), "v_c1": zeros_like_tree(c1),
        "m_c2": zeros_like_tree(c2), "v_c2": zeros_like_tree(c2),
        "log_alpha": jnp.asarray(math.log(0.05), jnp.float32),
        "m_alpha": jnp.asarray(0.0), "v_alpha": jnp.asarray(0.0),
        "step": jnp.asarray(0.0),
    }
    return tree


# ---------------------------------------------------------------------------
# LAD-TS / D2SAC-TS train step (SAC with a diffusion actor; Eqns 14-17).
# ---------------------------------------------------------------------------

def lad_train_step(state_flat, batch, b_dim: int, i_steps: int,
                   actor_loss_form: str = "standard",
                   alpha_autotune: bool = True):
    """One SAC update with the LADN diffusion actor.

    Args:
      state_flat: flat tensors per ``lad_state_spec(b_dim)``.
      batch: dict with
        s  [K,S], x  [K,B], a [K] i32, r [K], s2 [K,S], x2 [K,B],
        noise [I,K,B], noise2 [I,K,B].
      actor_loss_form: "standard" (discrete diffusion-SAC objective) or
        "paper" (the squared form of Eqn 15) — see DESIGN.md §5.
      alpha_autotune: apply the Eqn-16 dual update to alpha (fig8b runs
        with this off so the swept temperature stays fixed).

    Returns:
      (new_state_flat, metrics [critic_loss, actor_loss, alpha, entropy,
       q_mean]).
    """
    spec = lad_state_spec(b_dim)
    st = unpack_state(spec, state_flat)
    s, x, a, r = batch["s"], batch["x"], batch["a"], batch["r"]
    s2, x2 = batch["s2"], batch["x2"]
    noise, noise2 = batch["noise"], batch["noise2"]
    alpha = jnp.exp(st["log_alpha"])
    step = st["step"] + 1.0
    k = s.shape[0]
    rows = jnp.arange(k)

    # --- target value (soft state value under the current actor) --------
    _, pi2 = actor_fwd(st["actor"], x2, s2, noise2, i_steps, use_kernel=False)
    logpi2 = jnp.log(pi2 + 1e-8)
    qt = jnp.minimum(mlp_apply(st["t1"], s2), mlp_apply(st["t2"], s2))
    v_next = jnp.sum(pi2 * (qt - alpha * logpi2), axis=1)
    q_target = jax.lax.stop_gradient(r + GAMMA * v_next)

    # --- critic update (Eqn 14) -----------------------------------------
    def critic_loss_fn(cp):
        qa = mlp_apply(cp, s)[rows, a]
        return jnp.mean((qa - q_target) ** 2)

    cl1, g1 = jax.value_and_grad(critic_loss_fn)(st["c1"])
    cl2, g2 = jax.value_and_grad(critic_loss_fn)(st["c2"])
    c1, m_c1, v_c1 = adam_update(st["c1"], g1, st["m_c1"], st["v_c1"], step, LR_CRITIC)
    c2, m_c2, v_c2 = adam_update(st["c2"], g2, st["m_c2"], st["v_c2"], step, LR_CRITIC)

    # --- actor update (Eqn 15 / standard form) ---------------------------
    q_eval_all = jax.lax.stop_gradient(
        jnp.minimum(mlp_apply(c1, s), mlp_apply(c2, s))
    )

    def actor_loss_fn(ap):
        _, pi = actor_fwd(ap, x, s, noise, i_steps, use_kernel=False)
        logpi = jnp.log(pi + 1e-8)
        ent = -jnp.sum(pi * logpi, axis=1)
        if actor_loss_form == "paper":
            # Eqn 15 verbatim: mean((-alpha*H - pi(a)*Q_eval(s,a))^2).
            pia = pi[rows, a]
            qa = q_eval_all[rows, a]
            loss = jnp.mean((-alpha * ent - pia * qa) ** 2)
        else:
            # Standard discrete SAC objective with the diffusion actor.
            loss = jnp.mean(
                jnp.sum(pi * (alpha * logpi - q_eval_all), axis=1)
            )
        return loss, ent

    (al, ent), ga = jax.value_and_grad(actor_loss_fn, has_aux=True)(st["actor"])
    actor, m_a, v_a = adam_update(
        st["actor"], ga, st["m_actor"], st["v_actor"], step, LR_ACTOR
    )

    # --- temperature update (Eqn 16 dual form on log-alpha) -------------
    mean_ent = jnp.mean(ent)
    if alpha_autotune:
        # d/dalpha [(-H - H~) * alpha] = -H - H~ ; chain through exp().
        # Dual temperature update targeting H = -H~ (= 1 nat): raise
        # alpha when entropy is below target, lower it above. This is
        # Eqn 16 with the sign that actually performs entropy targeting
        # (the verbatim form anti-targets and collapses the policy; see
        # DESIGN.md '5).
        g_log_alpha = (mean_ent + TARGET_ENTROPY) * alpha
        m_al = ADAM_B1 * st["m_alpha"] + (1 - ADAM_B1) * g_log_alpha
        v_al = ADAM_B2 * st["v_alpha"] + (1 - ADAM_B2) * g_log_alpha**2
        mhat = m_al / (1.0 - ADAM_B1**step)
        vhat = v_al / (1.0 - ADAM_B2**step)
        log_alpha = jnp.clip(
            st["log_alpha"] - LR_ALPHA * mhat / (jnp.sqrt(vhat) + ADAM_EPS),
            LOG_ALPHA_MIN, LOG_ALPHA_MAX,
        )
    else:
        log_alpha, m_al, v_al = st["log_alpha"], st["m_alpha"], st["v_alpha"]

    # --- soft target update (Eqn 17) -------------------------------------
    t1 = {k2: TAU * c1[k2] + (1 - TAU) * st["t1"][k2] for k2 in c1}
    t2 = {k2: TAU * c2[k2] + (1 - TAU) * st["t2"][k2] for k2 in c2}

    new_tree = {
        "actor": actor, "c1": c1, "c2": c2, "t1": t1, "t2": t2,
        "m_actor": m_a, "v_actor": v_a,
        "m_c1": m_c1, "v_c1": v_c1, "m_c2": m_c2, "v_c2": v_c2,
        "log_alpha": log_alpha, "m_alpha": m_al, "v_alpha": v_al,
        "step": step,
    }
    metrics = jnp.stack(
        [cl1 + cl2, al, jnp.exp(log_alpha), mean_ent, jnp.mean(q_eval_all)]
    )
    return pack_state(spec, new_tree), metrics


# ---------------------------------------------------------------------------
# SAC-TS train step (categorical MLP actor; same losses minus diffusion).
# ---------------------------------------------------------------------------

def sac_train_step(state_flat, batch, b_dim: int,
                   alpha_autotune: bool = True):
    spec = sac_state_spec(b_dim)
    st = unpack_state(spec, state_flat)
    s, a, r, s2 = batch["s"], batch["a"], batch["r"], batch["s2"]
    alpha = jnp.exp(st["log_alpha"])
    step = st["step"] + 1.0
    k = s.shape[0]
    rows = jnp.arange(k)

    _, pi2 = sac_actor_fwd(st["actor"], s2)
    logpi2 = jnp.log(pi2 + 1e-8)
    qt = jnp.minimum(mlp_apply(st["t1"], s2), mlp_apply(st["t2"], s2))
    v_next = jnp.sum(pi2 * (qt - alpha * logpi2), axis=1)
    q_target = jax.lax.stop_gradient(r + GAMMA * v_next)

    def critic_loss_fn(cp):
        qa = mlp_apply(cp, s)[rows, a]
        return jnp.mean((qa - q_target) ** 2)

    cl1, g1 = jax.value_and_grad(critic_loss_fn)(st["c1"])
    cl2, g2 = jax.value_and_grad(critic_loss_fn)(st["c2"])
    c1, m_c1, v_c1 = adam_update(st["c1"], g1, st["m_c1"], st["v_c1"], step, LR_CRITIC)
    c2, m_c2, v_c2 = adam_update(st["c2"], g2, st["m_c2"], st["v_c2"], step, LR_CRITIC)

    q_eval_all = jax.lax.stop_gradient(
        jnp.minimum(mlp_apply(c1, s), mlp_apply(c2, s))
    )

    def actor_loss_fn(ap):
        _, pi = sac_actor_fwd(ap, s)
        logpi = jnp.log(pi + 1e-8)
        ent = -jnp.sum(pi * logpi, axis=1)
        loss = jnp.mean(jnp.sum(pi * (alpha * logpi - q_eval_all), axis=1))
        return loss, ent

    (al, ent), ga = jax.value_and_grad(actor_loss_fn, has_aux=True)(st["actor"])
    actor, m_a, v_a = adam_update(
        st["actor"], ga, st["m_actor"], st["v_actor"], step, LR_ACTOR
    )

    mean_ent = jnp.mean(ent)
    if alpha_autotune:
        # Dual temperature update targeting H = -H~ (= 1 nat): raise
        # alpha when entropy is below target, lower it above. This is
        # Eqn 16 with the sign that actually performs entropy targeting
        # (the verbatim form anti-targets and collapses the policy; see
        # DESIGN.md '5).
        g_log_alpha = (mean_ent + TARGET_ENTROPY) * alpha
        m_al = ADAM_B1 * st["m_alpha"] + (1 - ADAM_B1) * g_log_alpha
        v_al = ADAM_B2 * st["v_alpha"] + (1 - ADAM_B2) * g_log_alpha**2
        mhat = m_al / (1.0 - ADAM_B1**step)
        vhat = v_al / (1.0 - ADAM_B2**step)
        log_alpha = jnp.clip(
            st["log_alpha"] - LR_ALPHA * mhat / (jnp.sqrt(vhat) + ADAM_EPS),
            LOG_ALPHA_MIN, LOG_ALPHA_MAX,
        )
    else:
        log_alpha, m_al, v_al = st["log_alpha"], st["m_alpha"], st["v_alpha"]

    t1 = {k2: TAU * c1[k2] + (1 - TAU) * st["t1"][k2] for k2 in c1}
    t2 = {k2: TAU * c2[k2] + (1 - TAU) * st["t2"][k2] for k2 in c2}

    new_tree = {
        "actor": actor, "c1": c1, "c2": c2, "t1": t1, "t2": t2,
        "m_actor": m_a, "v_actor": v_a,
        "m_c1": m_c1, "v_c1": v_c1, "m_c2": m_c2, "v_c2": v_c2,
        "log_alpha": log_alpha, "m_alpha": m_al, "v_alpha": v_al,
        "step": step,
    }
    metrics = jnp.stack(
        [cl1 + cl2, al, jnp.exp(log_alpha), mean_ent, jnp.mean(q_eval_all)]
    )
    return pack_state(spec, new_tree), metrics


# ---------------------------------------------------------------------------
# DQN-TS train step.
# ---------------------------------------------------------------------------

def dqn_train_step(state_flat, batch, b_dim: int):
    """Standard DQN with a soft-updated target network (tau as elsewhere,
    keeping one update convention across methods; epsilon-greedy lives on
    the rust side)."""
    spec = dqn_state_spec(b_dim)
    st = unpack_state(spec, state_flat)
    s, a, r, s2 = batch["s"], batch["a"], batch["r"], batch["s2"]
    step = st["step"] + 1.0
    rows = jnp.arange(s.shape[0])

    q_next = jnp.max(mlp_apply(st["t"], s2), axis=1)
    target = jax.lax.stop_gradient(r + GAMMA * q_next)

    def loss_fn(qp):
        qa = mlp_apply(qp, s)[rows, a]
        return jnp.mean((qa - target) ** 2)

    loss, g = jax.value_and_grad(loss_fn)(st["q"])
    q, m_q, v_q = adam_update(st["q"], g, st["m_q"], st["v_q"], step, LR_CRITIC)
    t = {k2: TAU * q[k2] + (1 - TAU) * st["t"][k2] for k2 in q}

    new_tree = {"q": q, "t": t, "m_q": m_q, "v_q": v_q, "step": step}
    qmean = jnp.mean(mlp_apply(q, s))
    metrics = jnp.stack([loss, jnp.asarray(0.0), jnp.asarray(0.0),
                         jnp.asarray(0.0), qmean])
    return pack_state(spec, new_tree), metrics


# ---------------------------------------------------------------------------
# Toy generation model (the reSD3-m stand-in served by DEdgeAI workers).
# Weights are trace-time constants (fixed seed) — the model is a compute
# stand-in, not a trained generator (paper §VI.C: quality out of scope).
# ---------------------------------------------------------------------------

def _gen_weights():
    key = jax.random.PRNGKey(20240717)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    emb = jax.random.normal(k1, (GEN_VOCAB, GEN_COND)) * 0.3
    proj = jax.random.normal(k2, (GEN_COND, GEN_COND)) / math.sqrt(GEN_COND)
    w = jax.random.normal(k3, (GEN_LATENT, GEN_LATENT)) / math.sqrt(GEN_LATENT)
    u = jax.random.normal(k4, (GEN_COND, GEN_LATENT)) / math.sqrt(GEN_COND)
    return emb, proj, w, u


def genmodel_encode(tokens):
    """Toy CLIP: embed byte tokens [L] i32, mean-pool, project, tanh."""
    emb, proj, _, _ = _gen_weights()
    e = jnp.mean(emb[tokens], axis=0)
    return jnp.tanh(e @ proj)


def genmodel_step(latent, cond, step_idx):
    """One conditioned denoise step via the Layer-1 Pallas kernel.

    ``step_idx`` (f32 scalar, counts down z_n..1) sets the retention /
    update blend, mimicking a diffusion noise schedule.
    """
    from .kernels import sd_step

    _, _, w, u = _gen_weights()
    a = 1.0 - 0.08 / (1.0 + 0.1 * step_idx)
    b = 0.35 / (1.0 + 0.1 * step_idx)
    return sd_step.latent_step(latent, cond, w, u, a, b)
