"""AOT lowering: JAX graphs -> HLO *text* + manifest.json (build time).

Interchange is HLO text, NOT ``HloModuleProto.serialize()``: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted graph families (one HLO file per entry, all f32 unless noted):

  ladn_actor_fwd_b{B}_i{I}   inference actor (Pallas kernel inside)
  ladn_train_b{B}_i{I}[_*]   LAD/D2SAC SAC train step (jnp eps; autodiff)
  sac_actor_fwd_b{B}         categorical actor
  sac_train_b{B}             discrete SAC train step
  dqn_fwd_b{B}               Q network
  dqn_train_b{B}             DQN train step
  genmodel_encode            toy text encoder (prompt -> cond vector)
  genmodel_step              one conditioned latent denoise (Pallas)

``manifest.json`` records, per graph: file name, ordered input/output
specs (name/shape/dtype) and meta (family/kind/b/i/state_len), plus the
global hyper-parameters, so the rust runtime can initialize parameters,
feed inputs, and round-trip train state without any Python at run time.

Usage: ``python -m compile.aot --out-dir ../artifacts [--quick]``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

B_LIST = [5, 10, 20, 30, 40]   # 5 = the DEdgeAI five-Jetson prototype; rest = fig7b
I_LIST = [1, 2, 3, 5, 7, 10]   # fig8a sweep (b=20 only)
I_DEFAULT = 5                  # Table IV


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def state_input_specs(spec):
    return [spec_entry(n, s) for n, s in spec]


def lad_batch_avals(b_dim, i_steps, k=model.TRAIN_K):
    s_dim = model.state_dim(b_dim)
    return {
        "s": f32((k, s_dim)), "x": f32((k, b_dim)), "a": i32((k,)),
        "r": f32((k,)), "s2": f32((k, s_dim)), "x2": f32((k, b_dim)),
        "noise": f32((i_steps, k, b_dim)), "noise2": f32((i_steps, k, b_dim)),
    }


def lad_batch_specs(b_dim, i_steps, k=model.TRAIN_K):
    s_dim = model.state_dim(b_dim)
    return [
        spec_entry("batch.s", (k, s_dim)),
        spec_entry("batch.x", (k, b_dim)),
        spec_entry("batch.a", (k,), "i32"),
        spec_entry("batch.r", (k,)),
        spec_entry("batch.s2", (k, s_dim)),
        spec_entry("batch.x2", (k, b_dim)),
        spec_entry("batch.noise", (i_steps, k, b_dim)),
        spec_entry("batch.noise2", (i_steps, k, b_dim)),
    ]


def sac_batch_avals(b_dim, k=model.TRAIN_K):
    s_dim = model.state_dim(b_dim)
    return {
        "s": f32((k, s_dim)), "a": i32((k,)), "r": f32((k,)),
        "s2": f32((k, s_dim)),
    }


def sac_batch_specs(b_dim, k=model.TRAIN_K):
    s_dim = model.state_dim(b_dim)
    return [
        spec_entry("batch.s", (k, s_dim)),
        spec_entry("batch.a", (k,), "i32"),
        spec_entry("batch.r", (k,)),
        spec_entry("batch.s2", (k, s_dim)),
    ]


METRICS = ["critic_loss", "actor_loss", "alpha", "entropy", "q_mean"]


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.graphs = {}

    def emit(self, name, fn, avals, inputs, outputs, meta):
        """Lower ``fn(*avals)`` and record the manifest entry."""
        lowered = jax.jit(fn).lower(*avals)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.graphs[name] = {
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            "meta": meta,
        }
        print(f"  {name}: {len(text)} chars, {len(inputs)} inputs")


def emit_ladn(em, b_dim, i_steps, variants=False):
    s_dim = model.state_dim(b_dim)
    spec = model.lad_state_spec(b_dim)
    eps_shapes = model.mlp_shapes(b_dim + model.TEMB_DIM + s_dim, b_dim)
    n = model.ACT_BATCH

    # ---- inference forward (Pallas kernel on the request path) ----------
    def fwd(params_flat, x, s, noise):
        params = dict(zip(model.MLP_KEYS, params_flat))
        return model.actor_fwd(params, x, s, noise, i_steps, use_kernel=True)

    actor_param_specs = [
        spec_entry(f"actor.{k}", eps_shapes[k]) for k in model.MLP_KEYS
    ]
    em.emit(
        f"ladn_actor_fwd_b{b_dim}_i{i_steps}",
        fwd,
        (
            tuple(f32(eps_shapes[k]) for k in model.MLP_KEYS),
            f32((n, b_dim)), f32((n, s_dim)), f32((i_steps, n, b_dim)),
        ),
        actor_param_specs + [
            spec_entry("x_i", (n, b_dim)),
            spec_entry("s", (n, s_dim)),
            spec_entry("noise", (i_steps, n, b_dim)),
        ],
        [spec_entry("x_0", (n, b_dim)), spec_entry("pi", (n, b_dim))],
        {"family": "ladn", "kind": "actor_fwd", "b": b_dim, "i": i_steps,
         "state_len": len(model.MLP_KEYS)},
    )

    # ---- train step(s) ---------------------------------------------------
    def make_train(form, autotune):
        def train(state_flat, *batch_flat):
            keys = ["s", "x", "a", "r", "s2", "x2", "noise", "noise2"]
            batch = dict(zip(keys, batch_flat))
            return model.lad_train_step(
                list(state_flat), batch, b_dim, i_steps,
                actor_loss_form=form, alpha_autotune=autotune,
            )
        return train

    state_avals = tuple(f32(s) for _n, s in spec)
    batch = lad_batch_avals(b_dim, i_steps)
    batch_avals = tuple(batch[k] for k in
                        ["s", "x", "a", "r", "s2", "x2", "noise", "noise2"])
    out_specs = state_input_specs(spec) + [spec_entry("metrics", (5,))]
    in_specs = state_input_specs(spec) + lad_batch_specs(b_dim, i_steps)

    configs = [("", "standard", True)]
    if variants:
        configs += [("_noauto", "standard", False),
                    ("_paperloss", "paper", True)]
    for suffix, form, autotune in configs:
        em.emit(
            f"ladn_train_b{b_dim}_i{i_steps}{suffix}",
            make_train(form, autotune),
            (state_avals,) + batch_avals,
            in_specs,
            out_specs,
            {"family": "ladn", "kind": "train", "b": b_dim, "i": i_steps,
             "state_len": len(spec), "metrics": METRICS,
             "actor_loss": form, "alpha_autotune": autotune},
        )


def emit_sac(em, b_dim):
    s_dim = model.state_dim(b_dim)
    spec = model.sac_state_spec(b_dim)
    a_shapes = model.mlp_shapes(s_dim, b_dim)
    n = model.ACT_BATCH

    def fwd(params_flat, s):
        params = dict(zip(model.MLP_KEYS, params_flat))
        return model.sac_actor_fwd(params, s)

    em.emit(
        f"sac_actor_fwd_b{b_dim}",
        fwd,
        (tuple(f32(a_shapes[k]) for k in model.MLP_KEYS), f32((n, s_dim))),
        [spec_entry(f"actor.{k}", a_shapes[k]) for k in model.MLP_KEYS]
        + [spec_entry("s", (n, s_dim))],
        [spec_entry("logits", (n, b_dim)), spec_entry("pi", (n, b_dim))],
        {"family": "sac", "kind": "actor_fwd", "b": b_dim,
         "state_len": len(model.MLP_KEYS)},
    )

    def train(state_flat, *batch_flat):
        batch = dict(zip(["s", "a", "r", "s2"], batch_flat))
        return model.sac_train_step(list(state_flat), batch, b_dim)

    b = sac_batch_avals(b_dim)
    em.emit(
        f"sac_train_b{b_dim}",
        train,
        (tuple(f32(s) for _n, s in spec),
         b["s"], b["a"], b["r"], b["s2"]),
        state_input_specs(spec) + sac_batch_specs(b_dim),
        state_input_specs(spec) + [spec_entry("metrics", (5,))],
        {"family": "sac", "kind": "train", "b": b_dim,
         "state_len": len(spec), "metrics": METRICS},
    )


def emit_dqn(em, b_dim):
    s_dim = model.state_dim(b_dim)
    spec = model.dqn_state_spec(b_dim)
    q_shapes = model.mlp_shapes(s_dim, b_dim)
    n = model.ACT_BATCH

    def fwd(params_flat, s):
        params = dict(zip(model.MLP_KEYS, params_flat))
        return (model.mlp_apply(params, s),)

    em.emit(
        f"dqn_fwd_b{b_dim}",
        fwd,
        (tuple(f32(q_shapes[k]) for k in model.MLP_KEYS), f32((n, s_dim))),
        [spec_entry(f"q.{k}", q_shapes[k]) for k in model.MLP_KEYS]
        + [spec_entry("s", (n, s_dim))],
        [spec_entry("q_values", (n, b_dim))],
        {"family": "dqn", "kind": "fwd", "b": b_dim,
         "state_len": len(model.MLP_KEYS)},
    )

    def train(state_flat, *batch_flat):
        batch = dict(zip(["s", "a", "r", "s2"], batch_flat))
        return model.dqn_train_step(list(state_flat), batch, b_dim)

    b = sac_batch_avals(b_dim)
    em.emit(
        f"dqn_train_b{b_dim}",
        train,
        (tuple(f32(s) for _n, s in spec),
         b["s"], b["a"], b["r"], b["s2"]),
        state_input_specs(spec) + sac_batch_specs(b_dim),
        state_input_specs(spec) + [spec_entry("metrics", (5,))],
        {"family": "dqn", "kind": "train", "b": b_dim,
         "state_len": len(spec), "metrics": METRICS},
    )


def emit_genmodel(em):
    em.emit(
        "genmodel_encode",
        lambda tokens: (model.genmodel_encode(tokens),),
        (i32((model.GEN_TOKENS,)),),
        [spec_entry("tokens", (model.GEN_TOKENS,), "i32")],
        [spec_entry("cond", (model.GEN_COND,))],
        {"family": "genmodel", "kind": "encode", "state_len": 0},
    )
    em.emit(
        "genmodel_step",
        lambda latent, cond, idx: (model.genmodel_step(latent, cond, idx),),
        (f32((model.GEN_LATENT, model.GEN_LATENT)), f32((model.GEN_COND,)),
         f32(())),
        [
            spec_entry("latent", (model.GEN_LATENT, model.GEN_LATENT)),
            spec_entry("cond", (model.GEN_COND,)),
            spec_entry("step_idx", ()),
        ],
        [spec_entry("latent_out", (model.GEN_LATENT, model.GEN_LATENT))],
        {"family": "genmodel", "kind": "step", "state_len": 0},
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only b=20/i=5 graphs (fast dev iteration)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    em = Emitter(args.out_dir)

    b_list = [20] if args.quick else B_LIST
    for b_dim in b_list:
        i_list = [I_DEFAULT] if (args.quick or b_dim != 20) else I_LIST
        for i_steps in i_list:
            emit_ladn(em, b_dim, i_steps,
                      variants=(b_dim == 20 and i_steps == I_DEFAULT
                                and not args.quick))
        emit_sac(em, b_dim)
        emit_dqn(em, b_dim)
    emit_genmodel(em)

    manifest = {
        "version": 1,
        "hidden": model.HIDDEN,
        "temb_dim": model.TEMB_DIM,
        "beta_min": model.BETA_MIN,
        "beta_max": model.BETA_MAX,
        "act_batch": model.ACT_BATCH,
        "train_k": model.TRAIN_K,
        "gamma": model.GAMMA,
        "tau": model.TAU,
        "lr_actor": model.LR_ACTOR,
        "lr_critic": model.LR_CRITIC,
        "lr_alpha": model.LR_ALPHA,
        "target_entropy": model.TARGET_ENTROPY,
        "gen_latent": model.GEN_LATENT,
        "gen_cond": model.GEN_COND,
        "gen_vocab": model.GEN_VOCAB,
        "gen_tokens": model.GEN_TOKENS,
        "graphs": em.graphs,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(em.graphs)} graphs + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
