"""Build-time compile package: Layer-1 Pallas kernels, Layer-2 JAX graphs,
and the AOT pipeline that lowers them to HLO text for the rust runtime.
Never imported at run time."""
